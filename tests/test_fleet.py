"""Fleet layer: multi-host placement, cross-host snapshot migration, the
drain-weighted router, and the FleetSim/ClusterSim seam.

Fast tests drive schedulers, brokers, and stub replicas as pure metadata
— per-host conservation (every host's ``free + granted + escrow +
snapshot == budget``) is asserted after EVERY fleet event via
``FleetScheduler.check_invariants``.  The properties pinned down:

  (a) placement: ``spread``/``pack`` are deterministic capacity policies
      (droppable snapshot charge counts as capacity);
  (b) migration: moving a snapshot between hosts debits the source
      ledger, credits the destination ledger, charges the modeled
      inter-host copy (real bytes / configurable bandwidth + link
      latency) onto the entry, and is refused — nothing mutated — when
      no peer holds a restorable copy or the destination lacks room;
  (c) ``drain_weighted`` routing: start-path tiers (local warm > local
      snapshot > remote snapshot > cold) and WEIGHTED drain scoring
      (how many blocks a replica owes, not whether it owes), plus the
      ``drain_avoided`` accounting shared with ``snapshot_affinity``;
  (d) the seam: ``FleetSim`` with one host replays ``ClusterSim``
      exactly (stub schedules here; the bit-identical real-engine
      ``StepEvent`` regression is the slow test below).

The ``slow``-marked tests run real ``ServeEngine`` replicas: the
single-host StepEvent trace regression and the remote-restore E2E
(capture on host B, fleet migration, restore on host A tagged
``source="remote"`` with the copy charge, TTFT between local restore
and cold prefill).
"""
from collections import deque

import pytest

from repro.cluster import (ClusterSim, FleetScheduler, FleetSim,
                           HostMemoryBroker, Router)
from repro.cluster.snapshots import Snapshot
from repro.serving.request import PROFILES, Request

from conftest import StubReplica, fake_clock as _fake_clock, \
    mk_async_broker as _mk_async


def _mk_fleet(budgets, *, pool_units=None, bandwidth=1024.0, latency=0.5):
    """Fleet of sync brokers on a fake clock; ``budgets`` maps host ->
    budget units.  Bandwidth in bytes/virtual-second so modeled copy
    walls are exact small numbers."""
    sched = FleetScheduler(bandwidth_bytes_per_s=bandwidth,
                           link_latency_s=latency, clock=_fake_clock())
    for h, b in budgets.items():
        sched.add_host(h, HostMemoryBroker(
            b, clock=_fake_clock(), snapshot_pool_units=pool_units))
    return sched


# ---------------------------------------------------------- (a) placement


def test_place_spread_and_pack_deterministic():
    sched = _mk_fleet({"h0": 16, "h1": 16, "h2": 16})
    sched.brokers["h0"].register("x", 10)      # capacities: 6, 16, 16
    sched.check_invariants()
    assert sched.place("a", 4, policy="spread") == "h1"   # most free, tie->id
    sched.brokers["h1"].register("a", 4)       # boot: capacities 6, 12, 16
    assert sched.place("b", 4, policy="pack") == "h0"     # best fit
    sched.brokers["h0"].register("b", 4)       # capacities 2, 12, 16
    assert sched.place("c", 8, policy="pack") == "h1"     # h0 can't fit 8
    sched.check_invariants()
    assert sched.placements == {"a": "h1", "b": "h0", "c": "h1"}
    assert sched.host_of("a") == "h1" and sched.host_of("zz") is None
    assert sched.broker_of("b") is sched.brokers["h0"]
    assert sched.broker_of("zz") is None
    assert sched.report()["placements"]["a"] == "h1"
    with pytest.raises(AssertionError):
        sched.place("d", 99)                   # fits nowhere: loud
    with pytest.raises(AssertionError):
        sched.place("a", 1)                    # already placed


def test_capacity_counts_droppable_snapshot_charge():
    """A booting replica squeezes the destination pool, so snapshot units
    are reclaimable capacity for placement purposes."""
    sched = _mk_fleet({"h0": 8, "h1": 8}, pool_units=8)
    sched.brokers["h0"].register("x", 2)                  # free 6
    assert sched.brokers["h1"].snapshot_put("cnn", units=7)   # free 1
    sched.check_invariants()
    assert sched.capacity("h0") == 6
    assert sched.capacity("h1") == 8           # 1 free + 7 droppable
    assert sched.place("a", 7, policy="spread") == "h1"


# --------------------------------------------------------- (b) migration


def test_migration_scripted_per_host_conservation():
    """THE fleet acceptance property: a cross-host migration debits the
    source pool, credits the destination pool, charges the modeled copy
    — and every host's ledger conserves after every event."""
    sched = _mk_fleet({"h0": 16, "h1": 16}, pool_units=8,
                      bandwidth=1024.0, latency=0.5)
    src, dst = sched.brokers["h1"], sched.brokers["h0"]
    src.register("B", 4)
    dst.register("A", 4)
    sched.check_invariants()
    assert src.snapshot_put("cnn", units=3, nbytes=2048,
                            payload=object(), replica_id="B")
    sched.check_invariants()
    assert src.free_units == 9 and src.snapshot_units() == 3
    assert dst.snapshot_units() == 0

    rec = sched.ensure_local("cnn", "h0")
    sched.check_invariants()
    assert rec is not None
    assert (rec.key, rec.src, rec.dst) == ("cnn", "h1", "h0")
    assert rec.units == 3 and rec.nbytes == 2048
    # modeled copy: latency + bytes/bandwidth, on the fleet clock
    assert rec.copy_seconds == pytest.approx(0.5 + 2048 / 1024.0)
    # debit/credit landed on the right ledgers
    assert src.snapshot_units() == 0 and src.free_units == 12
    assert dst.snapshot_units() == 3 and dst.free_units == 9
    assert not src.snapshot_available("cnn")
    assert dst.snapshot_restorable("cnn")
    snap = dst.snapshots.peek("cnn")
    assert snap.origin_host == "h1"
    assert snap.copy_seconds == rec.copy_seconds
    assert sched.report()["migrations"] == 1
    assert sched.report()["migrated_snapshot_bytes"] == 2048

    # already local: ensure_local is a no-op, nothing new moves
    assert sched.ensure_local("cnn", "h0") is None
    sched.check_invariants()
    assert len(sched.migrations) == 1

    # the copy charge is paid exactly once
    assert snap.claim_copy() == rec.copy_seconds
    assert snap.claim_copy() == 0.0


def test_migration_refused_without_source_or_room():
    sched = _mk_fleet({"h0": 8, "h1": 8}, pool_units=4)
    sched.brokers["h0"].register("A", 2)
    sched.brokers["h1"].register("B", 2)
    # no peer holds the key at all
    assert sched.ensure_local("cnn", "h0") is None
    assert sched.migration_denied == 1
    # a metadata-only entry (no payload) can never serve a restore, so it
    # is not a migration source either
    assert sched.brokers["h1"].snapshot_put("cnn", units=2)
    sched.check_invariants()
    assert sched.ensure_local("cnn", "h0") is None
    assert sched.migration_denied == 2
    # destination without room: source keeps the snapshot, nothing moves
    assert sched.brokers["h1"].snapshot_put("bert", units=2,
                                            payload=object())
    sched.brokers["h0"].request_units("A", 6)             # drain h0 free
    sched.check_invariants()
    assert sched.brokers["h0"].free_units == 0
    assert sched.ensure_local("bert", "h0") is None
    sched.check_invariants()
    assert sched.migration_denied == 3
    assert sched.brokers["h1"].snapshot_restorable("bert")
    assert not sched.brokers["h0"].snapshot_available("bert")
    assert not sched.migrations


def test_migration_compounds_unpaid_copy_walls():
    """A snapshot migrated twice without a restore in between owes BOTH
    hops at its first restore (the transfer wall never silently drops) —
    and the second hop CONTENDS with the first: hop1 (h0->h1, started at
    clock 1.0, in flight until 1.0 + 1.25) still occupies h1's NIC when
    hop2 (h1->h2) starts at clock 2.0, so hop2's byte wall sees half the
    pipe."""
    sched = _mk_fleet({"h0": 8, "h1": 8, "h2": 8}, pool_units=4,
                      bandwidth=1024.0, latency=0.25)
    for h in ("h0", "h1", "h2"):
        sched.brokers[h].register(f"r{h}", 2)
    assert sched.brokers["h0"].snapshot_put("cnn", units=2, nbytes=1024,
                                            payload=object())
    hop1 = sched.migrate_snapshot("cnn", "h1")
    sched.check_invariants()
    hop2 = sched.migrate_snapshot("cnn", "h2")
    sched.check_invariants()
    assert hop1.copy_seconds == pytest.approx(0.25 + 1.0)
    assert hop2.copy_seconds == pytest.approx((0.25 + 1.0)      # hop1 owed
                                              + 0.25 + 2 * 1.0)
    snap = sched.brokers["h2"].snapshots.peek("cnn")
    assert snap.origin_host == "h1"
    assert snap.claim_copy() == pytest.approx(hop2.copy_seconds)


def test_snapshot_host_is_deterministic_and_excludes_dst():
    sched = _mk_fleet({"h0": 8, "h1": 8, "h2": 8}, pool_units=4)
    for h in ("h1", "h2"):
        assert sched.brokers[h].snapshot_put("cnn", units=1,
                                             payload=object())
    assert sched.snapshot_host("cnn") == "h1"             # lowest host id
    assert sched.snapshot_host("cnn", exclude="h1") == "h2"
    assert sched.snapshot_host("nope") is None


# --------------------------------------------- (c) drain-weighted routing


class _FakeEngine:
    def __init__(self, load, warm=()):
        self._load = load
        self.warm = {name: [(0.0, "rid", 0)] for name in warm}

    def load(self):
        return self._load


def _req(profile="cnn"):
    return Request(rid="x", profile=PROFILES[profile], submit_s=0.0)


def test_drain_weighted_scores_by_owed_magnitude():
    """Unlike the binary dodge, a replica owing FEW blocks outranks one
    owing many — even when the big debtor is less loaded."""
    broker, sinks = _mk_async(24, [("a", 2), ("b", 12), ("c", 8)],
                              loads={"a": 9, "b": 0, "c": 4})
    broker.request_grant("a", 16)              # free 2 -> order 12 b, 2 c
    owed_b = broker.open_order_units("b")
    owed_c = broker.open_order_units("c")
    assert owed_b > owed_c > 0                 # b idlest -> biggest order
    engines = {"b": _FakeEngine(0), "c": _FakeEngine(4)}
    r = Router("drain_weighted", broker=broker)
    # the binary dodge ties b and c (both draining) and takes b by load;
    # weighted scoring prefers c, the smaller debtor
    assert r.route(_req(), engines) == "c"
    assert r.drain_avoided == 1
    # drain the orders: pure load order returns (b wins again)
    for rid in ("b", "c"):
        for o in sinks[rid]:
            broker.fulfill_order(o.order_id, o.remaining)
    broker.check_invariants()
    assert r.route(_req(), engines) == "b"
    assert r.drain_avoided == 1


def test_drain_weighted_tiers_warm_then_local_then_remote():
    sched = _mk_fleet({"h0": 8, "h1": 8}, pool_units=4)
    sched.brokers["h0"].register("a", 2)
    sched.brokers["h1"].register("b", 2)
    sched.placements.update({"a": "h0", "b": "h1"})
    assert sched.brokers["h1"].snapshot_put("cnn", units=1,
                                            payload=object())
    r = Router("drain_weighted", fleet=sched)
    # tier 0: the warm row wins even on the most loaded replica
    engines = {"a": _FakeEngine(0), "b": _FakeEngine(9, warm=("cnn",))}
    assert r.route(_req(), engines) == "b"
    assert r.warm_routes == 1
    # tier 1: no warm row anywhere -> the replica co-hosted with the
    # snapshot wins (local restore), despite higher load
    engines = {"a": _FakeEngine(0), "b": _FakeEngine(5)}
    assert r.route(_req(), engines) == "b"
    assert r.snapshot_routes == 1
    # tier 2: snapshot only on a host with no candidate replica -> remote
    # for every candidate; load decides, the migration hook localizes
    engines = {"a": _FakeEngine(3)}
    assert r.route(_req(), engines) == "a"
    assert r.remote_routes == 1
    # tier 3: nothing cached anywhere -> plain least-loaded, uncounted
    engines = {"a": _FakeEngine(3), "b": _FakeEngine(1)}
    assert r.route(_req("html"), engines) == "b"
    assert (r.warm_routes, r.snapshot_routes, r.remote_routes) == (1, 1, 1)


def test_drain_avoided_counted_under_snapshot_affinity():
    """The accounting fix: snapshot_affinity's dodge of a mid-reclaim
    victim now increments ``drain_avoided`` (it used to count only under
    power_of_two)."""
    broker, sinks = _mk_async(8, [("a", 2), ("b", 6)], pool_units=8)
    broker.request_grant("b", 3)               # a is now draining
    broker.release_units("b", 2)
    assert broker.snapshot_put("cnn", units=1, payload=object())
    engines = {"a": _FakeEngine(0), "b": _FakeEngine(5)}
    r = Router("snapshot_affinity", broker=broker)
    assert r.route(_req(), engines) == "b"     # dodged the less-loaded a
    assert r.drain_avoided == 1
    assert r.snapshot_routes == 1


# ----------------------------------------------- (d) FleetSim / ClusterSim


def _stub_script(sim_cls, **kw):
    """One deterministic stub schedule (requester grant + victim drain +
    decode overlap) run through the given sim class; returns the full
    event history per replica + metrics."""
    broker = HostMemoryBroker(16, async_reclaim=True, clock=_fake_clock())
    a = StubReplica("a", broker, units=4, decode_steps=10)
    b = StubReplica("b", broker, units=12)
    g = a.request(8)
    assert g.pending == 8
    reqs = [Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0),
            Request(rid="r1", profile=PROFILES["bert"], submit_s=2.0)]
    sim = sim_cls({"a": a, "b": b}, broker=broker, **kw)
    m = sim.run(reqs, max_virtual_s=100)
    broker.check_invariants()
    return {"a": a.events, "b": b.events}, m


def test_fleetsim_single_host_replays_clustersim_stub_schedule():
    """The refactor seam, fast: FleetSim with one host produces the
    identical event history and metrics as ClusterSim on the same
    scripted stub schedule."""

    def cluster(engines, broker):
        return ClusterSim(engines, Router("least_loaded"), broker)

    def fleet(engines, broker):
        return FleetSim({"host0": engines}, Router("least_loaded"),
                        brokers={"host0": broker})

    ev_c, m_c = _stub_script(lambda engines, broker=None:
                             cluster(engines, broker))
    ev_f, m_f = _stub_script(lambda engines, broker=None:
                             fleet(engines, broker))
    assert ev_c == ev_f                        # full event histories
    m_c.pop("per_replica"), m_f.pop("per_replica")
    m_c.pop("broker"), m_f.pop("broker")
    assert m_c == m_f


def test_fleetsim_migrates_at_route_time_with_conservation():
    """Two stub hosts: an arrival pinned to host h0 whose pool lacks the
    snapshot pulls it over from h1 at route time; per-host conservation
    holds after every tick (stubs check their broker each tick) and the
    fleet metrics surface the migration."""
    sched = FleetScheduler(bandwidth_bytes_per_s=1024.0,
                           link_latency_s=0.5)
    b0 = HostMemoryBroker(16, async_reclaim=True, clock=_fake_clock(),
                          snapshot_pool_units=4)
    b1 = HostMemoryBroker(16, async_reclaim=True, clock=_fake_clock(),
                          snapshot_pool_units=4)
    sched.add_host("h0", b0)
    sched.add_host("h1", b1)
    a = StubReplica("a", b0, units=4)
    b = StubReplica("b", b1, units=4)
    assert b1.snapshot_put("cnn", units=2, nbytes=512, payload=object(),
                           replica_id="b")
    sched.check_invariants()
    sim = FleetSim({"h0": {"a": a}, "h1": {"b": b}},
                   Router(route_fn=lambda r, e: "a"), scheduler=sched)
    m = sim.run([Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0)],
                max_virtual_s=100)
    sched.check_invariants()
    assert m["completed"] == 1
    assert m["snapshot_migrations"] == 1
    assert m["fleet"]["migrations"] == 1
    assert b0.snapshot_restorable("cnn")       # localized at route time
    assert not b1.snapshot_available("cnn")
    rec = sched.migrations[0]
    assert (rec.src, rec.dst) == ("h1", "h0")
    assert rec.copy_seconds == pytest.approx(0.5 + 512 / 1024.0)
    # stamped on the fleet clock: routed at t=0, before any tick advanced
    assert rec.at == 0.0


def test_fleetsim_no_migration_for_warm_target():
    """The route-time hook skips the copy when the chosen replica holds a
    warm row — an adopt beats any restore, the transfer would be waste."""
    sched = FleetScheduler(clock=_fake_clock())
    b0 = HostMemoryBroker(16, clock=_fake_clock(), snapshot_pool_units=4)
    b1 = HostMemoryBroker(16, clock=_fake_clock(), snapshot_pool_units=4)
    sched.add_host("h0", b0)
    sched.add_host("h1", b1)
    a = StubReplica("a", b0, units=4, decode_steps=2)
    b = StubReplica("b", b1, units=4)
    a.warm["cnn"] = [(0.0, "w0", 0)]
    assert b1.snapshot_put("cnn", units=2, payload=object())
    sim = FleetSim({"h0": {"a": a}, "h1": {"b": b}},
                   Router(route_fn=lambda r, e: "a"), scheduler=sched)
    sim.run([Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0)],
            max_virtual_s=50)
    assert not sched.migrations                # warm target: no copy
    assert b1.snapshot_available("cnn")


def test_metrics_p99_hardening():
    """latency_p99 is None (not a 1-sample numpy percentile) until at
    least 2 requests completed; p50 appears from the first completion."""
    broker = HostMemoryBroker(16, clock=_fake_clock())
    a = StubReplica("a", broker, units=4, decode_steps=3)
    sim = ClusterSim({"a": a}, Router(route_fn=lambda r, e: "a"), broker)
    m = sim.run([Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0)],
                max_virtual_s=50)
    assert m["completed"] == 1
    assert m["latency_p50"] is not None
    assert m["latency_p99"] is None            # 1 sample: no tail stat
    a2 = StubReplica("a2", HostMemoryBroker(16, clock=_fake_clock()),
                     units=4, decode_steps=3)
    sim2 = ClusterSim({"a2": a2}, Router(route_fn=lambda r, e: "a2"))
    m2 = sim2.run([Request(rid="q0", profile=PROFILES["cnn"], submit_s=0.0),
                   Request(rid="q1", profile=PROFILES["cnn"], submit_s=0.0)],
                  max_virtual_s=50)
    assert m2["completed"] == 2
    assert isinstance(m2["latency_p99"], float)


# --------------------------------------------- engine integration (slow)


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs.base import get_config, reduced
    from repro.core.arena import ArenaSpec
    from repro.models import model as M
    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    return cfg, params, spec


def _fleet_reqs():
    from repro.serving.tracegen import assign_profiles, bursty_trace
    quiet = bursty_trace(6.0, 0.9, burst_x=1.0, burst_len=0.0, seed=2)
    burst = [4.0 + t for t in bursty_trace(4.0, 3.0, burst_x=3.0,
                                           burst_at=(0.0,), burst_len=2.0,
                                           seed=3)]
    reqs = [Request(rid=f"b{i}", profile=p, submit_s=t)
            for i, (t, p) in enumerate(assign_profiles(quiet, PROFILES, 2))]
    reqs += [Request(rid=f"a{i}", profile=p, submit_s=t)
             for i, (t, p) in enumerate(assign_profiles(burst, PROFILES, 3))]
    return reqs


class _FakeClock:
    def __init__(self, step=1e-4):
        self._t = 0.0
        self._step = step

    def perf_counter(self):
        self._t += self._step
        return self._t


@pytest.mark.slow
def test_fleetsim_one_host_stepevent_trace_bit_identical(setup,
                                                         monkeypatch):
    """THE seam regression: a contended two-replica trace (steals, async
    orders, routing) produces a bit-identical StepEvent trace — every
    (t, kind, wall, detail) tuple on every replica — through ClusterSim
    and through FleetSim with that one host."""
    import repro.core.elastic as elastic_mod
    import repro.core.hotmem as hotmem_mod
    import repro.core.vanilla as vanilla_mod
    import repro.serving.engine as engine_mod
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition

    def run(mk_sim):
        clock = _FakeClock()
        for mod in (engine_mod, elastic_mod, hotmem_mod, vanilla_mod):
            monkeypatch.setattr(mod, "time", clock)
        broker = HostMemoryBroker(budget_units=10 * bpp,
                                  async_reclaim=True)
        engines = {rid: ServeEngine(cfg, params, spec, mode="hotmem",
                                    keep_alive=3.0, seed=i, broker=broker,
                                    replica_id=rid)
                   for i, rid in enumerate(("A", "B"))}
        sim = mk_sim(engines, broker)
        m = sim.run(_fleet_reqs(), max_virtual_s=2000)
        broker.check_invariants()
        traces = {rid: [(e.t, e.kind, e.wall_s, e.detail)
                        for e in eng.events]
                  for rid, eng in engines.items()}
        return traces, m

    t_c, m_c = run(lambda engines, broker:
                   ClusterSim(engines, Router("power_of_two"), broker))
    t_f, m_f = run(lambda engines, broker:
                   FleetSim({"host0": engines}, Router("power_of_two"),
                            brokers={"host0": broker}))
    assert t_c == t_f
    assert m_c["completed"] == m_f["completed"] > 0
    assert m_c["routed"] == m_f["routed"]
    assert m_c["broker"]["steals"] == m_f["broker"]["steals"] > 0


@pytest.mark.slow
def test_fleet_migration_ttft_ordering(setup):
    """The fleet acceptance property, measured: across a 2-host fleet
    the remote-migrated restore's TTFT lands strictly between the local
    restore and the cold prefill.  Medians of 3 samples per path (the
    same cycles the ``fleet_migration`` benchmark rows report) — a
    single-shot restore wall is noise-dominated on a busy CPU."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.figures import _fleet_migration_medians
    cfg, params, spec = setup
    (local_us, remote_us, cold_us), sched, A = _fleet_migration_medians(
        cfg, params, spec, repeats=3)
    assert A.remote_restore_starts == 3 and len(sched.migrations) == 3
    copy_us = sched.migrations[-1].copy_seconds * 1e6
    assert local_us < remote_us < cold_us, \
        (local_us, remote_us, cold_us, copy_us)


@pytest.mark.slow
def test_fleet_remote_restore_end_to_end(setup):
    """Capture on host B, migrate, restore on host A: the restore event
    is tagged ``source="remote"`` with the origin host and the modeled
    copy charge, the engine counts it, per-host conservation holds, and
    the copy is paid exactly once."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    sched = FleetScheduler()                   # default bandwidth/latency
    bA = HostMemoryBroker(budget_units=12 * bpp,
                          snapshot_pool_units=4 * bpp)
    bB = HostMemoryBroker(budget_units=12 * bpp,
                          snapshot_pool_units=4 * bpp)
    sched.add_host("h0", bA)
    sched.add_host("h1", bB)
    A = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                    seed=0, broker=bA, replica_id="A")
    B = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                    seed=1, broker=bB, replica_id="B")
    sched.placements.update({"A": "h0", "B": "h1"})
    empty = deque()

    def run_one(eng, rid, prof="cnn"):
        eng.submit(Request(rid=rid, profile=PROFILES[prof],
                           submit_s=eng.now))
        while eng.active or eng.pending:
            eng._tick(empty)
        req = next(r for r in eng.done if r.rid == rid)
        return (req.first_token_s - req.admitted_s)

    # A: a LOCAL restore first (own capture after expiry), for the tag
    run_one(A, "jit0")
    for prof, entries in list(A.warm.items()):
        for (_, rid, _row) in entries:
            A.arena.finish(rid)
        A.warm[prof] = []
    run_one(A, "c0")
    A.now += A.keep_alive + 1.0
    A._recycle_idle()                          # capture cnn on h0
    sched.check_invariants()
    run_one(A, "s0")
    assert A.restore_starts == 1 and A.remote_restore_starts == 0
    local_ev = [e for e in A.events if e.kind == "restore"][-1]
    assert local_ev.detail["source"] == "local"
    bA.snapshot_drop("cnn")                    # forget, for the remote run
    sched.check_invariants()

    # B runs bert, captures it on h1 — A has never seen bert's KV
    run_one(B, "jitB", prof="bert")
    B.now += B.keep_alive + 1.0
    B._recycle_idle()
    sched.check_invariants()
    assert bB.snapshot_restorable("bert")
    assert not bA.snapshot_available("bert")

    rec = sched.ensure_local("bert", "h0")     # the fleet migration
    sched.check_invariants()
    assert rec is not None and rec.copy_seconds > 0
    assert not bB.snapshot_available("bert")

    # A's expired warm row for cnn is gone; admit bert -> REMOTE restore
    A.now += A.keep_alive + 1.0
    A._recycle_idle()
    run_one(A, "r0", prof="bert")
    sched.check_invariants()
    assert A.remote_restore_starts == 1 and A.restore_starts == 2
    ev = [e for e in A.events if e.kind == "restore"][-1]
    assert ev.detail["source"] == "remote"
    assert ev.detail["origin"] == "h1"
    assert ev.detail["copy_s"] == pytest.approx(rec.copy_seconds)
    assert ev.wall_s >= rec.copy_seconds       # the copy was charged
    # paid once: a second restore of the now-local entry is local again
    A.now += A.keep_alive + 1.0
    A._recycle_idle()
    run_one(A, "r1", prof="bert")
    assert A.remote_restore_starts == 1 and A.restore_starts == 3
    assert [e for e in A.events if e.kind == "restore"][-1] \
        .detail["source"] == "local"


def test_migration_preserves_tenant_attribution():
    """A cross-host snapshot migration keeps the entry's OWNER tenant:
    the source ledger credits and the destination ledger charges the
    same tenant account, and the destination host's protection rule
    covers the migrated entry exactly as a local capture."""
    sched = FleetScheduler()
    bA = HostMemoryBroker(8, async_reclaim=True, snapshot_pool_units=4,
                          tenants={"a": 4, "b": 4}, clock=_fake_clock())
    bB = HostMemoryBroker(8, async_reclaim=True, snapshot_pool_units=4,
                          tenants={"a": 4, "b": 4}, clock=_fake_clock())
    sched.add_host("h0", bA)
    sched.add_host("h1", bB)
    assert bB.snapshot_put("fn", units=2, payload=("kv", "fn"),
                           nbytes=256, tenant="a")
    assert bB.ledger.tenant_snapshot("a") == 2
    sched.check_invariants()

    rec = sched.ensure_local("fn", "h0")
    assert rec is not None and rec.copy_seconds > 0
    snap = bA.snapshots.peek("fn")
    assert snap is not None and snap.tenant == "a"   # owner travelled
    assert bA.ledger.tenant_snapshot("a") == 2
    assert bB.ledger.tenant_snapshot("a") == 0
    sched.check_invariants()

    # on the destination, tenant b's pressure cannot squeeze it: a's
    # usage there (2) is already below a's sub-budget (4)
    bA.register("vb", 3, load=lambda: 0, tenant="b", mode="model")
    g = bA.request_grant("vb", 5)                    # free 3 + deficit 2
    assert g.granted == 3
    assert bA.squeeze_log == []
    assert bA.snapshots.peek("fn") is not None
    sched.check_invariants()
