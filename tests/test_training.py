"""Training substrate: loss decreases, grad-accum equivalence, bf16-grad
compression path, deterministic data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import model as M
from repro.training.data import DataConfig, SyntheticTokens
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_batch_labels, make_train_step


def test_loss_decreases(rng):
    cfg = reduced(get_config("tinyllama-1.1b"))
    params = M.init_params(cfg, rng)
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3,
                                                    warmup_steps=1)))
    toks = jax.random.randint(rng, (4, 32), 0, cfg.vocab_size)
    batch = make_batch_labels(toks)               # fixed batch -> memorize
    losses = []
    for _ in range(20):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::5]


def test_grad_accum_equivalence(rng):
    """accum=2 must match accum=1 on the same global batch (within bf16)."""
    cfg = reduced(get_config("qwen2-1.5b"))
    params = M.init_params(cfg, rng)
    toks = jax.random.randint(rng, (4, 16), 0, cfg.vocab_size)
    batch = make_batch_labels(toks)
    outs = {}
    for accum in (1, 2):
        state = {"params": jax.tree.map(jnp.copy, params),
                 "opt": init_opt_state(params)}
        step = jax.jit(make_train_step(cfg, grad_accum=accum))
        state, m = step(state, batch)
        outs[accum] = (float(m["loss"]), float(m["grad_norm"]))
    assert abs(outs[1][0] - outs[2][0]) < 2e-2
    assert abs(outs[1][1] - outs[2][1]) / (outs[1][1] + 1e-9) < 5e-2


def test_data_pipeline_deterministic_and_sharded():
    dc = DataConfig(vocab_size=512, seq_len=16, global_batch=8)
    a = SyntheticTokens(dc).batch_at(7)
    b = SyntheticTokens(dc).batch_at(7)
    np.testing.assert_array_equal(a, b)           # resume-safe
    shards = [SyntheticTokens(dc, num_shards=4, shard_id=i).batch_at(7)
              for i in range(4)]
    assert all(s.shape == (2, 16) for s in shards)
    assert not np.array_equal(shards[0], shards[1])


def test_optimizer_master_weights_fp32(rng):
    cfg = reduced(get_config("tinyllama-1.1b"))
    params = M.init_params(cfg, rng)
    opt = init_opt_state(params)
    for leaf in jax.tree.leaves(opt["master"]):
        assert leaf.dtype == jnp.float32
    for leaf in jax.tree.leaves(params):
        assert leaf.dtype in (jnp.bfloat16, jnp.float32)
