"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(assigned deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.kv_compact import kv_compact
from repro.kernels.paged_attention import paged_attention
from repro.kernels.partition_attention import partition_attention

DTYPES = [jnp.float32, jnp.bfloat16]
SHAPES = [  # (P, T, HKV, G, DH, block_t)
    (2, 32, 1, 1, 16, 8),
    (4, 64, 2, 3, 32, 16),
    (3, 128, 4, 2, 64, 64),
    (1, 256, 2, 7, 128, 128),
]


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("window", [0, 24])
def test_partition_attention_sweep(shape, dtype, window):
    p, t, hkv, g, dh, bt = shape
    rng = np.random.default_rng(hash((shape, window)) % 2 ** 31)
    q = jnp.asarray(rng.normal(size=(p, hkv, g, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), dtype)
    pos = jnp.asarray(rng.integers(0, 3 * t, size=(p,)), jnp.int32)
    out = partition_attention(q, k, v, pos, window=window, block_t=bt)
    want = ref.partition_attention(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("cap", [0.0, 30.0])
def test_partition_attention_softcap(dtype, cap):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 2, 2, 32)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), dtype)
    pos = jnp.asarray([10, 63], jnp.int32)
    out = partition_attention(q, k, v, pos, logit_cap=cap, block_t=16)
    want = ref.partition_attention(q, k, v, pos, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("nb,bt,mb", [(8, 8, 4), (16, 16, 8), (32, 8, 6)])
def test_paged_attention_sweep(dtype, nb, bt, mb):
    p, hkv, g, dh = 3, 2, 2, 32
    rng = np.random.default_rng(nb * bt)
    q = jnp.asarray(rng.normal(size=(p, hkv, g, dh)), dtype)
    kp = jnp.asarray(rng.normal(size=(nb, bt, hkv, dh)), dtype)
    vp = jnp.asarray(rng.normal(size=(nb, bt, hkv, dh)), dtype)
    tables = np.full((p, mb), -1, np.int32)
    pos = np.zeros((p,), np.int32)
    for i in range(p):
        nblk = int(rng.integers(1, mb + 1))
        tables[i, :nblk] = rng.choice(nb, size=nblk, replace=False)
        pos[i] = nblk * bt - int(rng.integers(1, bt))
    out = paged_attention(q, kp, vp, jnp.asarray(tables), jnp.asarray(pos))
    want = ref.paged_attention(q, kp, vp, jnp.asarray(tables),
                               jnp.asarray(pos))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES + [jnp.int32])
@pytest.mark.parametrize("nb,bt,m", [(8, 4, 3), (16, 8, 8), (64, 16, 31)])
def test_kv_compact_sweep(dtype, nb, bt, m):
    rng = np.random.default_rng(nb + m)
    if dtype == jnp.int32:
        pool = jnp.asarray(rng.integers(0, 100, size=(nb, bt, 2, 8)), dtype)
    else:
        pool = jnp.asarray(rng.normal(size=(nb, bt, 2, 8)), dtype)
    src = rng.choice(nb, size=m, replace=False)
    dst = rng.choice(nb, size=m, replace=False)
    src_j = jnp.asarray(src, jnp.int32)
    dst_j = jnp.asarray(dst, jnp.int32)
    got = kv_compact(pool, src_j, dst_j)
    want = ref.kv_compact(pool, src_j, dst_j, m)
    assert jnp.array_equal(got, want)


def test_paged_equals_partition_when_contiguous():
    """The two layouts must agree when the block table is the identity —
    the kernel-level statement of 'same math, different placement'."""
    rng = np.random.default_rng(0)
    p, hkv, g, dh, bt, nblk = 2, 2, 2, 32, 16, 4
    t = bt * nblk
    q = jnp.asarray(rng.normal(size=(p, hkv, g, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), jnp.float32)
    pos = jnp.asarray([t - 1, t // 2], jnp.int32)
    part = partition_attention(q, k, v, pos, block_t=bt)
    kp = k.reshape(p * nblk, bt, hkv, dh)
    vp = v.reshape(p * nblk, bt, hkv, dh)
    tables = jnp.asarray(
        [[i * nblk + j for j in range(nblk)] for i in range(p)], jnp.int32)
    paged = paged_attention(q, kp, vp, tables, pos)
    np.testing.assert_allclose(np.asarray(part), np.asarray(paged),
                               atol=1e-5, rtol=1e-5)
