"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(assigned deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.kv_compact import kv_compact
from repro.kernels.paged_attention import paged_attention
from repro.kernels.partition_attention import partition_attention

DTYPES = [jnp.float32, jnp.bfloat16]
SHAPES = [  # (P, T, HKV, G, DH, block_t)
    (2, 32, 1, 1, 16, 8),
    (4, 64, 2, 3, 32, 16),
    (3, 128, 4, 2, 64, 64),
    (1, 256, 2, 7, 128, 128),
]


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("window", [0, 24])
def test_partition_attention_sweep(shape, dtype, window):
    p, t, hkv, g, dh, bt = shape
    rng = np.random.default_rng(hash((shape, window)) % 2 ** 31)
    q = jnp.asarray(rng.normal(size=(p, hkv, g, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), dtype)
    pos = jnp.asarray(rng.integers(0, 3 * t, size=(p,)), jnp.int32)
    out = partition_attention(q, k, v, pos, window=window, block_t=bt)
    want = ref.partition_attention(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("cap", [0.0, 30.0])
def test_partition_attention_softcap(dtype, cap):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 2, 2, 32)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), dtype)
    pos = jnp.asarray([10, 63], jnp.int32)
    out = partition_attention(q, k, v, pos, logit_cap=cap, block_t=16)
    want = ref.partition_attention(q, k, v, pos, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("nb,bt,mb", [(8, 8, 4), (16, 16, 8), (32, 8, 6)])
def test_paged_attention_sweep(dtype, nb, bt, mb):
    p, hkv, g, dh = 3, 2, 2, 32
    rng = np.random.default_rng(nb * bt)
    q = jnp.asarray(rng.normal(size=(p, hkv, g, dh)), dtype)
    kp = jnp.asarray(rng.normal(size=(nb, bt, hkv, dh)), dtype)
    vp = jnp.asarray(rng.normal(size=(nb, bt, hkv, dh)), dtype)
    tables = np.full((p, mb), -1, np.int32)
    pos = np.zeros((p,), np.int32)
    for i in range(p):
        nblk = int(rng.integers(1, mb + 1))
        tables[i, :nblk] = rng.choice(nb, size=nblk, replace=False)
        pos[i] = nblk * bt - int(rng.integers(1, bt))
    out = paged_attention(q, kp, vp, jnp.asarray(tables), jnp.asarray(pos))
    want = ref.paged_attention(q, kp, vp, jnp.asarray(tables),
                               jnp.asarray(pos))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES + [jnp.int32])
@pytest.mark.parametrize("nb,bt,m", [(8, 4, 3), (16, 8, 8), (64, 16, 31)])
def test_kv_compact_sweep(dtype, nb, bt, m):
    rng = np.random.default_rng(nb + m)
    if dtype == jnp.int32:
        pool = jnp.asarray(rng.integers(0, 100, size=(nb, bt, 2, 8)), dtype)
    else:
        pool = jnp.asarray(rng.normal(size=(nb, bt, 2, 8)), dtype)
    src = rng.choice(nb, size=m, replace=False)
    dst = rng.choice(nb, size=m, replace=False)
    src_j = jnp.asarray(src, jnp.int32)
    dst_j = jnp.asarray(dst, jnp.int32)
    got = kv_compact(pool, src_j, dst_j)
    want = ref.kv_compact(pool, src_j, dst_j, m)
    assert jnp.array_equal(got, want)


# ------------------------------------- fused snapshot capture/restore


SNAP_CONFIGS = ["qwen2-7b", "mamba2-780m", "recurrentgemma-2b"]


def _snap_caches(config, rows, t, seed=0):
    """Reduced config + cache tree with non-degenerate contents (cache
    leaves are zero-initialized, which would make byte-identity vacuous)."""
    from repro.configs.base import get_config, reduced
    from repro.models import model as M
    cfg = reduced(get_config(config))
    rng = np.random.default_rng(seed)
    caches = jax.tree.map(
        lambda x: jnp.asarray(rng.standard_normal(x.shape), dtype=x.dtype),
        M.init_caches(cfg, rows, t))
    return cfg, caches


def _subjaxprs_of(v):
    tname = type(v).__name__
    if tname == "ClosedJaxpr":
        return [v.jaxpr]
    if tname == "Jaxpr":
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for item in v for j in _subjaxprs_of(item)]
    return []


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in _subjaxprs_of(v):
                n += _count_pallas_calls(sub)
    return n


@pytest.mark.parametrize("config", SNAP_CONFIGS)
@pytest.mark.parametrize("t,n", [(64, 1), (128, 3)])
def test_snapshot_capture_pallas_vs_ref(config, t, n):
    """The fused gather stages byte-identical blobs on both impls, for
    attention-only, SSM, and rglru-hybrid cache trees."""
    from repro.models import model as M
    _, caches = _snap_caches(config, n + 2, t, seed=t + n)
    layout = M.cache_row_layout(caches)
    rows = jnp.asarray(list(range(1, n + 1))[::-1], jnp.int32)  # unordered
    a = np.asarray(jax.device_get(
        M.cache_read_rows(caches, rows, layout=layout, impl="pallas")))
    b = np.asarray(jax.device_get(
        M.cache_read_rows(caches, rows, layout=layout, impl="ref")))
    assert a.shape == (n, layout.total_elems)
    assert a.tobytes() == b.tobytes()


@pytest.mark.parametrize("config", SNAP_CONFIGS)
def test_snapshot_restore_pallas_vs_ref_and_untouched_rows(config):
    """The fused scatter lands the staged bytes exactly where ref lands
    them — and rows OUTSIDE the restored set keep their old bytes."""
    from repro.models import model as M
    _, caches = _snap_caches(config, 5, 128, seed=11)
    layout = M.cache_row_layout(caches)
    rows = jnp.asarray([3, 1], jnp.int32)
    rng = np.random.default_rng(12)
    blob = jnp.asarray(
        rng.standard_normal((2, layout.total_elems)), dtype=layout.dtype)
    got = M.cache_write_rows(caches, blob, rows, layout=layout,
                             impl="pallas")
    want = M.cache_write_rows(caches, blob, rows, layout=layout, impl="ref")
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert np.asarray(g).tobytes() == np.asarray(w).tobytes()
    # untouched rows (0, 2, 4) are bit-identical to the pre-restore state
    keep = jnp.asarray([0, 2, 4], jnp.int32)
    before = np.asarray(jax.device_get(
        M.cache_read_rows(caches, keep, layout=layout, impl="ref")))
    after = np.asarray(jax.device_get(
        M.cache_read_rows(got, keep, layout=layout, impl="ref")))
    assert before.tobytes() == after.tobytes()


@pytest.mark.parametrize("config", SNAP_CONFIGS)
def test_snapshot_blob_matches_legacy_per_leaf_bytes(config):
    """Layout contract: the fused blob's byte image IS the legacy
    per-leaf ``tobytes()`` concatenation, so page digests built on the
    blob match digests built the old way (BENCH_9 dedup baselines pin
    these digests)."""
    import hashlib
    from repro.models import model as M
    _, caches = _snap_caches(config, 4, 128, seed=5)
    layout = M.cache_row_layout(caches)
    row = 2
    blob = np.asarray(jax.device_get(M.cache_read_rows(
        caches, jnp.asarray([row], jnp.int32), layout=layout, impl="ref")))
    legacy = b"".join(
        np.asarray(leaf).tobytes()
        for leaf in jax.tree.leaves(jax.device_get(
            M.cache_read_row(caches, row))))
    assert blob.tobytes() == legacy
    assert hashlib.sha256(blob.tobytes()).hexdigest() == \
        hashlib.sha256(legacy).hexdigest()


def test_snapshot_roundtrip_bit_identity():
    """capture -> restore -> capture reproduces the staged bytes."""
    from repro.models import model as M
    _, caches = _snap_caches("qwen2-7b", 4, 64, seed=3)
    layout = M.cache_row_layout(caches)
    rows = jnp.asarray([0, 3], jnp.int32)
    blob = M.cache_read_rows(caches, rows, layout=layout, impl="pallas")
    fresh = jax.tree.map(jnp.zeros_like, caches)
    restored = M.cache_write_rows(fresh, blob, rows, layout=layout,
                                  impl="pallas")
    again = M.cache_read_rows(restored, rows, layout=layout, impl="pallas")
    assert np.asarray(blob).tobytes() == np.asarray(again).tobytes()


def test_snapshot_fused_single_launch():
    """Dispatch-count half of the acceptance bar: the whole capture (and
    the whole restore) of a rows batch is ONE pallas_call in the traced
    computation — not one per leaf."""
    from repro.kernels import kv_snapshot, ops
    from repro.models import model as M
    _, caches = _snap_caches("qwen2-7b", 4, 64)
    leaves, axes, _ = M.cache_flat_axes(caches)
    layout = M.cache_row_layout(caches)
    rows = jnp.asarray([1, 2], jnp.int32)
    assert len(leaves) > 1, "contract is vacuous with a single leaf"

    cap = jax.make_jaxpr(lambda lv, rw: kv_snapshot.snapshot_capture(
        lv, rw, layout=layout, interpret=True))(tuple(leaves), rows)
    assert _count_pallas_calls(cap.jaxpr) == 1

    blob = jnp.zeros((2, layout.total_elems), layout.dtype)
    rst = jax.make_jaxpr(lambda lv, bl, rw: kv_snapshot.snapshot_restore(
        lv, bl, rw, layout=layout, interpret=True))(
            tuple(leaves), blob, rows)
    assert _count_pallas_calls(rst.jaxpr) == 1

    # and the ops-level dispatchers stay fused end-to-end (the jit eqn
    # wraps the same single launch)
    cap2 = jax.make_jaxpr(lambda lv, rw: ops.kv_snapshot_capture(
        lv, rw, layout=layout, impl="pallas"))(tuple(leaves), rows)
    assert _count_pallas_calls(cap2.jaxpr) == 1
    rst2 = jax.make_jaxpr(lambda lv, bl, rw: ops.kv_snapshot_restore(
        lv, bl, rw, layout=layout, impl="pallas"))(tuple(leaves), blob, rows)
    assert _count_pallas_calls(rst2.jaxpr) == 1


def test_engine_capture_restore_transfer_counts():
    """Transfer-count half of the acceptance bar, on a real engine: a
    snapshot capture is ONE fused launch + ONE device->host copy of
    exactly the row's bytes, and a staged restore is ONE fused launch +
    at most ONE host->device copy."""
    from collections import deque
    from repro.cluster import HostMemoryBroker
    from repro.configs.base import get_config, reduced
    from repro.core.arena import ArenaSpec
    from repro.kernels import kv_snapshot
    from repro.models import model as M
    from repro.serving.engine import ServeEngine
    from repro.serving.request import PROFILES, Request

    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                      seed=0, broker=broker, replica_id="A")

    def run_one(rid):
        eng.submit(Request(rid=rid, profile=PROFILES["cnn"],
                           submit_s=eng.now))
        empty = deque()
        while eng.active or eng.pending:
            eng._tick(empty)

    run_one("c0")                              # cold start, warm row parked
    layout = eng._snapshot_layout()

    kv_snapshot.reset_stats()
    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()                        # capture on expiry
    s = kv_snapshot.STATS
    assert s["capture_launches"] == 1
    assert s["d2h_transfers"] == 1
    assert s["d2h_bytes"] == layout.row_bytes
    assert s["h2d_transfers"] == 0

    kv_snapshot.reset_stats()
    run_one("s0")                              # restore from the pool
    assert eng.restore_starts == 1
    s = kv_snapshot.STATS
    assert s["restore_launches"] == 1
    assert s["h2d_transfers"] <= 1
    assert s["h2d_bytes"] <= layout.row_bytes
    assert s["d2h_transfers"] == 0             # restore never reads back


def test_paged_equals_partition_when_contiguous():
    """The two layouts must agree when the block table is the identity —
    the kernel-level statement of 'same math, different placement'."""
    rng = np.random.default_rng(0)
    p, hkv, g, dh, bt, nblk = 2, 2, 2, 32, 16, 4
    t = bt * nblk
    q = jnp.asarray(rng.normal(size=(p, hkv, g, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), jnp.float32)
    pos = jnp.asarray([t - 1, t // 2], jnp.int32)
    part = partition_attention(q, k, v, pos, block_t=bt)
    kp = k.reshape(p * nblk, bt, hkv, dh)
    vp = v.reshape(p * nblk, bt, hkv, dh)
    tables = jnp.asarray(
        [[i * nblk + j for j in range(nblk)] for i in range(p)], jnp.int32)
    paged = paged_attention(q, kp, vp, tables, pos)
    np.testing.assert_allclose(np.asarray(part), np.asarray(paged),
                               atol=1e-5, rtol=1e-5)
