"""Property-based tests on the managers' invariants — the paper's
correctness core: partitions never double-booked, refcounts sound, HotMem
reclaim never migrates, vanilla reclaim preserves every live block — plus
the async broker's conservation law under arbitrary order interleavings.

Two drivers over the same op-stream interpreters:
  * hypothesis (when installed) explores adversarial op sequences;
  * a seeded pure-pytest fallback (``random.Random(0)``) replays fixed
    pseudo-random sequences, so the invariants are exercised on every run
    even where hypothesis is absent (this container).
"""
import itertools
import random
from collections import deque

import pytest

from repro.cluster import BudgetLedger, HostMemoryBroker
from repro.core.arena import ArenaSpec
from repro.core.hotmem import HotMemManager
from repro.core.vanilla import VanillaPagedManager

SPEC = ArenaSpec(partition_tokens=64, n_partitions=8, block_tokens=16,
                 bytes_per_partition=1024)

OP_KINDS = ("reserve", "grow", "release", "fork", "plug", "unplug")

BROKER_OP_KINDS = ("request", "drain", "release", "claim", "cancel",
                   "snap_put", "snap_get", "snap_drop")

LEDGER_OP_KINDS = ("take", "release", "escrow_in", "escrow_out",
                   "snap_charge", "snap_credit")


# ---------------------------------------------------------------- drivers


def run_hotmem_ops(ops):
    """Interpret an op stream against HotMemManager, checking invariants
    after every op; returns the live-request set for the final assert."""
    m = HotMemManager(SPEC, plugged=4)
    live = set()
    for kind, arg in ops:
        rid = f"r{arg}"
        if kind == "reserve" and rid not in live:
            if m.reserve(rid) is not None:
                live.add(rid)
        elif kind == "grow" and rid in live:
            if not m.grow(rid, 16):
                live.discard(rid)           # killed
        elif kind == "release" and rid in live:
            m.release(rid, force=True)
            live.discard(rid)
        elif kind == "fork" and rid in live:
            m.fork(rid)
            m.release(rid)                  # net refcount unchanged
        elif kind == "plug":
            m.plug(arg)
        elif kind == "unplug":
            ev = m.unplug(arg)
            assert ev.migrated_bytes == 0   # THE paper property
            assert ev.migrated_blocks == 0
        m.check_invariants()
    assert m.live_partitions == len(live)
    return m, live


def run_vanilla_ops(ops, seed=1):
    m = VanillaPagedManager(SPEC, seed=seed)
    live = set()
    for kind, arg in ops:
        rid = f"r{arg}"
        if kind == "reserve" and rid not in live:
            if m.reserve(rid) is not None:
                live.add(rid)
        elif kind == "grow" and rid in live:
            if m.grow(rid, 16) is None:
                live.discard(rid)
        elif kind == "release" and rid in live:
            m.release(rid)
            live.discard(rid)
        elif kind == "unplug":
            before = {r: list(m.block_table(r)) for r in live}
            k, moves = m.shrink_plan(arg * SPEC.blocks_per_partition)
            ev = m.apply_shrink(k, moves)
            # every live block survives (possibly remapped), none lost
            for r in live:
                assert len(m.block_table(r)) == len(before[r])
            assert ev.migrated_blocks == len(moves)
        elif kind == "plug":
            m.plug(arg * SPEC.blocks_per_partition)
        m.check_invariants()
    return m, live


def _seeded_ops(seed, n_ops):
    """Pure-pytest fallback op stream: same shape as the hypothesis one."""
    rng = random.Random(seed)
    ops = []
    for _ in range(n_ops):
        kind = rng.choice(OP_KINDS)
        if kind in ("plug", "unplug"):
            ops.append((kind, rng.randint(1, 4)))
        else:
            ops.append((kind, rng.randint(0, 15)))
    return ops


def run_async_broker_ops(ops, n_replicas, budget=32):
    """Interpret an op stream against an async ``HostMemoryBroker`` across
    2–4 replicas: arbitrary interleavings of plug requests (grant + order
    issuance, preceded by snapshot squeezes), partial order fulfillments,
    natural releases, grant claims, cancels, and snapshot pool traffic
    (insert / restore-lookup / drop).  After EVERY op: the conservation
    invariant ``free + granted + escrow + snapshot_units == budget`` holds
    and no grant ever carries more units than were requested."""
    clock = itertools.count(1)
    broker = HostMemoryBroker(budget, async_reclaim=True,
                              clock=lambda: float(next(clock)),
                              snapshot_pool_units=budget // 2)
    rids = [f"v{i}" for i in range(n_replicas)]
    order_q = {r: deque() for r in rids}
    grants = {r: [] for r in rids}
    per_replica = budget // (n_replicas + 1)     # leave some pool free
    for i, r in enumerate(rids):
        broker.register(r, per_replica, load=lambda i=i: i,
                        order_sink=order_q[r].append, mode="hotmem")
    broker.check_invariants()

    def front_open(r):
        q = order_q[r]
        while q and not q[0].open:
            q.popleft()
        return q[0] if q else None

    for kind, a, b in ops:
        r = rids[a % n_replicas]
        if kind == "request":
            g = broker.request_grant(r, 1 + b % 8)
            if not g.done or g.available:
                grants[r].append(g)
        elif kind == "drain":
            o = front_open(r)
            if o is not None:
                broker.fulfill_order(o.order_id, 1 + b % 4)
        elif kind == "release":
            have = broker.granted[r]
            if have:
                broker.release_units(r, 1 + b % have)
        elif kind == "claim":
            for g in grants[r]:
                broker.claim_grant(g)
        elif kind == "cancel":
            o = front_open(r)
            if o is not None:
                broker.cancel_order(o.order_id)
        elif kind == "snap_put":
            broker.snapshot_put(f"k{b % 4}", units=1 + b % 4,
                                nbytes=64 * (1 + b % 4), replica_id=r)
        elif kind == "snap_get":
            broker.snapshot_lookup(f"k{b % 4}")
        elif kind == "snap_drop":
            broker.snapshot_drop(f"k{b % 4}")
        broker.check_invariants()                # conservation, every event
        for glist in grants.values():
            for g in glist:
                assert g.fulfilled <= g.requested, \
                    "granted more than requested"
                assert g.pending >= 0 and g.available >= 0
    return broker


def _seeded_broker_ops(seed, n_ops):
    rng = random.Random(seed)
    return [(rng.choice(BROKER_OP_KINDS), rng.randint(0, 15),
             rng.randint(0, 15)) for _ in range(n_ops)]


def run_ledger_ops(ops, budget=32, n_replicas=3):
    """Interpret an op stream directly against ``BudgetLedger`` — the
    extracted conservation core the broker (and every fleet host) now
    delegates to.  Arbitrary legal interleavings of grant fills, unplug
    releases, escrow flows, and snapshot charges keep

        free + sum(granted) + escrow + snapshot == budget

    after EVERY op (``check`` is the broker-independent code path)."""
    led = BudgetLedger(budget)
    rids = [f"r{i}" for i in range(n_replicas)]
    for r in rids:
        led.carve(r, budget // (2 * n_replicas))
    led.check()
    for kind, a, b in ops:
        rid = rids[a % n_replicas]
        if kind == "take":
            got = led.take_free(rid, b % 8)
            assert 0 <= got <= b % 8           # clipped, never overdrafts
        elif kind == "release":
            have = led.granted[rid]
            if have:
                led.release(rid, 1 + b % have)
        elif kind == "escrow_in":
            have = led.granted[rid]
            if have:
                led.escrow_fill(rid, 1 + b % have)
        elif kind == "escrow_out":
            if led.escrow_units:
                led.escrow_claim(rid, 1 + b % led.escrow_units)
        elif kind == "snap_charge":
            if led.free_units:
                led.snapshot_charge(1 + b % led.free_units)
        elif kind == "snap_credit":
            if led.snapshot_units:
                led.snapshot_credit(1 + b % led.snapshot_units)
        led.check()                            # conservation, every event
    return led


def _seeded_ledger_ops(seed, n_ops):
    rng = random.Random(seed)
    return [(rng.choice(LEDGER_OP_KINDS), rng.randint(0, 15),
             rng.randint(0, 15)) for _ in range(n_ops)]


# ------------------------------------------------- hypothesis (if present)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.one_of(
            st.tuples(st.just("reserve"), st.integers(0, 15)),
            st.tuples(st.just("grow"), st.integers(0, 15)),
            st.tuples(st.just("release"), st.integers(0, 15)),
            st.tuples(st.just("fork"), st.integers(0, 15)),
            st.tuples(st.just("plug"), st.integers(1, 4)),
            st.tuples(st.just("unplug"), st.integers(1, 4)),
        ),
        min_size=1, max_size=60,
    )

    @settings(max_examples=200, deadline=None)
    @given(OPS)
    def test_hotmem_invariants(ops):
        run_hotmem_ops(ops)

    @settings(max_examples=200, deadline=None)
    @given(OPS)
    def test_vanilla_invariants(ops):
        run_vanilla_ops(ops)

    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 8), st.integers(0, 8))
    def test_hotmem_unplug_only_free_suffix(n_live, k):
        _check_unplug_only_free_suffix(n_live, k)

    @settings(max_examples=100, deadline=None)
    @given(st.integers(2, 8))
    def test_waitqueue_fifo_wakeup(n):
        _check_waitqueue_fifo(n)

    BROKER_OPS = st.lists(
        st.tuples(st.sampled_from(BROKER_OP_KINDS),
                  st.integers(0, 15), st.integers(0, 15)),
        min_size=1, max_size=80,
    )

    @settings(max_examples=200, deadline=None)
    @given(BROKER_OPS, st.integers(2, 4))
    def test_async_broker_conservation(ops, n_replicas):
        run_async_broker_ops(ops, n_replicas)

    LEDGER_OPS = st.lists(
        st.tuples(st.sampled_from(LEDGER_OP_KINDS),
                  st.integers(0, 15), st.integers(0, 15)),
        min_size=1, max_size=80,
    )

    @settings(max_examples=200, deadline=None)
    @given(LEDGER_OPS, st.integers(2, 4))
    def test_ledger_conservation(ops, n_replicas):
        run_ledger_ops(ops, n_replicas=n_replicas)
else:
    def test_hypothesis_missing_is_reported():
        """Collection must stay green without hypothesis; the seeded
        fallback below carries the invariant coverage."""
        pytest.importorskip("hypothesis")


# ------------------------------------------------ seeded pytest fallback


@pytest.mark.parametrize("seed", range(25))
def test_hotmem_invariants_seeded(seed):
    run_hotmem_ops(_seeded_ops(seed, 60))


@pytest.mark.parametrize("seed", range(25))
def test_vanilla_invariants_seeded(seed):
    run_vanilla_ops(_seeded_ops(1000 + seed, 60))


@pytest.mark.parametrize("seed", range(25))
@pytest.mark.parametrize("n_replicas", [2, 3, 4])
def test_async_broker_conservation_seeded(seed, n_replicas):
    run_async_broker_ops(_seeded_broker_ops(2000 + seed, 80), n_replicas)


@pytest.mark.parametrize("seed", range(25))
@pytest.mark.parametrize("n_replicas", [2, 3, 4])
def test_ledger_conservation_seeded(seed, n_replicas):
    run_ledger_ops(_seeded_ledger_ops(3000 + seed, 80),
                   n_replicas=n_replicas)


def test_ledger_scripted_flows_and_overdraft_guards():
    """Exact-arithmetic walk through every ledger verb, plus the loud
    failures: each account rejects an overdraft AT the flow (so a leak
    is attributed to the illegal move, not discovered later)."""
    led = BudgetLedger(16)
    led.carve("a", 4)
    led.carve("b", 4)                          # free 8
    led.check()
    assert led.take_free("a", 5) == 5          # free 3, a=9
    assert led.take_free("b", 9) == 3          # clipped to the pool
    led.check()
    assert led.free_units == 0 and led.granted == {"a": 9, "b": 7}
    led.escrow_fill("b", 2)                    # b=5, escrow 2
    led.escrow_claim("a", 2)                   # a=11, escrow 0
    led.release("a", 6)                        # free 6
    led.snapshot_charge(5)                     # free 1, snapshot 5
    led.snapshot_credit(0)                     # explicit no-op
    led.snapshot_credit(5)                     # free 6, snapshot 0
    led.check()
    assert led.free_units == 6
    assert led.granted == {"a": 5, "b": 5}
    assert led.escrow_units == 0 and led.snapshot_units == 0
    # overdraft guards, one per account
    with pytest.raises(AssertionError):
        led.carve("a", 1)                      # double boot
    with pytest.raises(AssertionError):
        led.carve("c", 7)                      # beyond the free pool
    with pytest.raises(AssertionError):
        led.release("a", 6)                    # more than granted
    with pytest.raises(AssertionError):
        led.escrow_fill("a", 6)                # more than the victim holds
    with pytest.raises(AssertionError):
        led.escrow_claim("a", 1)               # empty escrow
    with pytest.raises(AssertionError):
        led.snapshot_charge(7)                 # beyond the free pool
    with pytest.raises(AssertionError):
        led.snapshot_credit(1)                 # empty pool charge
    with pytest.raises(AssertionError):
        led.take_free("nope", 1)               # unregistered replica
    led.check()                                # guards mutated nothing


def _check_unplug_only_free_suffix(n_live, k):
    """Unplug must never touch a live partition (zero-migration is only
    possible because shrink takes empty partitions exclusively)."""
    m = HotMemManager(SPEC)
    rids = [f"r{i}" for i in range(n_live)]
    for r in rids:
        m.reserve(r)
    owned = {m.partition_of(r) for r in rids}
    ev = m.unplug(k)
    assert ev.reclaimed_units <= SPEC.n_partitions - n_live
    for r in rids:
        assert m.partition_of(r) in owned
    m.check_invariants()


def _check_waitqueue_fifo(n):
    m = HotMemManager(SPEC, plugged=1)
    assert m.reserve("holder") is not None
    for i in range(n):
        assert m.reserve(f"w{i}") is None
    woken = m.release("holder")
    assert woken == "w0"                    # FIFO
    assert list(m.waitqueue) == [f"w{i}" for i in range(1, n)]


@pytest.mark.parametrize("n_live,k", [(n, k) for n in range(1, 9)
                                      for k in (0, 2, 4, 8)])
def test_unplug_only_free_suffix_seeded(n_live, k):
    _check_unplug_only_free_suffix(n_live, k)


@pytest.mark.parametrize("n", range(2, 9))
def test_waitqueue_fifo_wakeup_seeded(n):
    _check_waitqueue_fifo(n)
