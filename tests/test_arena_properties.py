"""Property-based tests on the managers' invariants — the paper's
correctness core: partitions never double-booked, refcounts sound, HotMem
reclaim never migrates, vanilla reclaim preserves every live block.

Two drivers over the same op-stream interpreters:
  * hypothesis (when installed) explores adversarial op sequences;
  * a seeded pure-pytest fallback (``random.Random(0)``) replays fixed
    pseudo-random sequences, so the invariants are exercised on every run
    even where hypothesis is absent (this container).
"""
import random

import pytest

from repro.core.arena import ArenaSpec
from repro.core.hotmem import HotMemManager
from repro.core.vanilla import VanillaPagedManager

SPEC = ArenaSpec(partition_tokens=64, n_partitions=8, block_tokens=16,
                 bytes_per_partition=1024)

OP_KINDS = ("reserve", "grow", "release", "fork", "plug", "unplug")


# ---------------------------------------------------------------- drivers


def run_hotmem_ops(ops):
    """Interpret an op stream against HotMemManager, checking invariants
    after every op; returns the live-request set for the final assert."""
    m = HotMemManager(SPEC, plugged=4)
    live = set()
    for kind, arg in ops:
        rid = f"r{arg}"
        if kind == "reserve" and rid not in live:
            if m.reserve(rid) is not None:
                live.add(rid)
        elif kind == "grow" and rid in live:
            if not m.grow(rid, 16):
                live.discard(rid)           # killed
        elif kind == "release" and rid in live:
            m.release(rid, force=True)
            live.discard(rid)
        elif kind == "fork" and rid in live:
            m.fork(rid)
            m.release(rid)                  # net refcount unchanged
        elif kind == "plug":
            m.plug(arg)
        elif kind == "unplug":
            ev = m.unplug(arg)
            assert ev.migrated_bytes == 0   # THE paper property
            assert ev.migrated_blocks == 0
        m.check_invariants()
    assert m.live_partitions == len(live)
    return m, live


def run_vanilla_ops(ops, seed=1):
    m = VanillaPagedManager(SPEC, seed=seed)
    live = set()
    for kind, arg in ops:
        rid = f"r{arg}"
        if kind == "reserve" and rid not in live:
            if m.reserve(rid) is not None:
                live.add(rid)
        elif kind == "grow" and rid in live:
            if m.grow(rid, 16) is None:
                live.discard(rid)
        elif kind == "release" and rid in live:
            m.release(rid)
            live.discard(rid)
        elif kind == "unplug":
            before = {r: list(m.block_table(r)) for r in live}
            k, moves = m.shrink_plan(arg * SPEC.blocks_per_partition)
            ev = m.apply_shrink(k, moves)
            # every live block survives (possibly remapped), none lost
            for r in live:
                assert len(m.block_table(r)) == len(before[r])
            assert ev.migrated_blocks == len(moves)
        elif kind == "plug":
            m.plug(arg * SPEC.blocks_per_partition)
        m.check_invariants()
    return m, live


def _seeded_ops(seed, n_ops):
    """Pure-pytest fallback op stream: same shape as the hypothesis one."""
    rng = random.Random(seed)
    ops = []
    for _ in range(n_ops):
        kind = rng.choice(OP_KINDS)
        if kind in ("plug", "unplug"):
            ops.append((kind, rng.randint(1, 4)))
        else:
            ops.append((kind, rng.randint(0, 15)))
    return ops


# ------------------------------------------------- hypothesis (if present)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.one_of(
            st.tuples(st.just("reserve"), st.integers(0, 15)),
            st.tuples(st.just("grow"), st.integers(0, 15)),
            st.tuples(st.just("release"), st.integers(0, 15)),
            st.tuples(st.just("fork"), st.integers(0, 15)),
            st.tuples(st.just("plug"), st.integers(1, 4)),
            st.tuples(st.just("unplug"), st.integers(1, 4)),
        ),
        min_size=1, max_size=60,
    )

    @settings(max_examples=200, deadline=None)
    @given(OPS)
    def test_hotmem_invariants(ops):
        run_hotmem_ops(ops)

    @settings(max_examples=200, deadline=None)
    @given(OPS)
    def test_vanilla_invariants(ops):
        run_vanilla_ops(ops)

    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 8), st.integers(0, 8))
    def test_hotmem_unplug_only_free_suffix(n_live, k):
        _check_unplug_only_free_suffix(n_live, k)

    @settings(max_examples=100, deadline=None)
    @given(st.integers(2, 8))
    def test_waitqueue_fifo_wakeup(n):
        _check_waitqueue_fifo(n)
else:
    def test_hypothesis_missing_is_reported():
        """Collection must stay green without hypothesis; the seeded
        fallback below carries the invariant coverage."""
        pytest.importorskip("hypothesis")


# ------------------------------------------------ seeded pytest fallback


@pytest.mark.parametrize("seed", range(25))
def test_hotmem_invariants_seeded(seed):
    run_hotmem_ops(_seeded_ops(seed, 60))


@pytest.mark.parametrize("seed", range(25))
def test_vanilla_invariants_seeded(seed):
    run_vanilla_ops(_seeded_ops(1000 + seed, 60))


def _check_unplug_only_free_suffix(n_live, k):
    """Unplug must never touch a live partition (zero-migration is only
    possible because shrink takes empty partitions exclusively)."""
    m = HotMemManager(SPEC)
    rids = [f"r{i}" for i in range(n_live)]
    for r in rids:
        m.reserve(r)
    owned = {m.partition_of(r) for r in rids}
    ev = m.unplug(k)
    assert ev.reclaimed_units <= SPEC.n_partitions - n_live
    for r in rids:
        assert m.partition_of(r) in owned
    m.check_invariants()


def _check_waitqueue_fifo(n):
    m = HotMemManager(SPEC, plugged=1)
    assert m.reserve("holder") is not None
    for i in range(n):
        assert m.reserve(f"w{i}") is None
    woken = m.release("holder")
    assert woken == "w0"                    # FIFO
    assert list(m.waitqueue) == [f"w{i}" for i in range(1, n)]


@pytest.mark.parametrize("n_live,k", [(n, k) for n in range(1, 9)
                                      for k in (0, 2, 4, 8)])
def test_unplug_only_free_suffix_seeded(n_live, k):
    _check_unplug_only_free_suffix(n_live, k)


@pytest.mark.parametrize("n", range(2, 9))
def test_waitqueue_fifo_wakeup_seeded(n):
    _check_waitqueue_fifo(n)
