"""Property-based tests on the managers' invariants — the paper's
correctness core: partitions never double-booked, refcounts sound, HotMem
reclaim never migrates, vanilla reclaim preserves every live block — plus
the async broker's conservation law under arbitrary order interleavings.

Two drivers over the same op-stream interpreters:
  * hypothesis (when installed) explores adversarial op sequences;
  * a seeded pure-pytest fallback (``random.Random(0)``) replays fixed
    pseudo-random sequences, so the invariants are exercised on every run
    even where hypothesis is absent (this container).
"""
import itertools
import random
from collections import deque

import pytest

from repro.cluster import BudgetLedger, HostMemoryBroker
from repro.core.arena import ArenaSpec
from repro.core.hotmem import HotMemManager
from repro.core.vanilla import VanillaPagedManager

SPEC = ArenaSpec(partition_tokens=64, n_partitions=8, block_tokens=16,
                 bytes_per_partition=1024)

OP_KINDS = ("reserve", "grow", "release", "fork", "plug", "unplug")

BROKER_OP_KINDS = ("request", "drain", "release", "claim", "cancel",
                   "snap_put", "snap_get", "snap_drop")

LEDGER_OP_KINDS = ("take", "release", "escrow_in", "escrow_out",
                   "snap_charge", "snap_credit")


# ---------------------------------------------------------------- drivers


def run_hotmem_ops(ops):
    """Interpret an op stream against HotMemManager, checking invariants
    after every op; returns the live-request set for the final assert."""
    m = HotMemManager(SPEC, plugged=4)
    live = set()
    for kind, arg in ops:
        rid = f"r{arg}"
        if kind == "reserve" and rid not in live:
            if m.reserve(rid) is not None:
                live.add(rid)
        elif kind == "grow" and rid in live:
            if not m.grow(rid, 16):
                live.discard(rid)           # killed
        elif kind == "release" and rid in live:
            m.release(rid, force=True)
            live.discard(rid)
        elif kind == "fork" and rid in live:
            m.fork(rid)
            m.release(rid)                  # net refcount unchanged
        elif kind == "plug":
            m.plug(arg)
        elif kind == "unplug":
            ev = m.unplug(arg)
            assert ev.migrated_bytes == 0   # THE paper property
            assert ev.migrated_blocks == 0
        m.check_invariants()
    assert m.live_partitions == len(live)
    return m, live


def run_vanilla_ops(ops, seed=1):
    m = VanillaPagedManager(SPEC, seed=seed)
    live = set()
    for kind, arg in ops:
        rid = f"r{arg}"
        if kind == "reserve" and rid not in live:
            if m.reserve(rid) is not None:
                live.add(rid)
        elif kind == "grow" and rid in live:
            if m.grow(rid, 16) is None:
                live.discard(rid)
        elif kind == "release" and rid in live:
            m.release(rid)
            live.discard(rid)
        elif kind == "unplug":
            before = {r: list(m.block_table(r)) for r in live}
            k, moves = m.shrink_plan(arg * SPEC.blocks_per_partition)
            ev = m.apply_shrink(k, moves)
            # every live block survives (possibly remapped), none lost
            for r in live:
                assert len(m.block_table(r)) == len(before[r])
            assert ev.migrated_blocks == len(moves)
        elif kind == "plug":
            m.plug(arg * SPEC.blocks_per_partition)
        m.check_invariants()
    return m, live


def _seeded_ops(seed, n_ops):
    """Pure-pytest fallback op stream: same shape as the hypothesis one."""
    rng = random.Random(seed)
    ops = []
    for _ in range(n_ops):
        kind = rng.choice(OP_KINDS)
        if kind in ("plug", "unplug"):
            ops.append((kind, rng.randint(1, 4)))
        else:
            ops.append((kind, rng.randint(0, 15)))
    return ops


def run_async_broker_ops(ops, n_replicas, budget=32):
    """Interpret an op stream against an async ``HostMemoryBroker`` across
    2–4 replicas: arbitrary interleavings of plug requests (grant + order
    issuance, preceded by snapshot squeezes), partial order fulfillments,
    natural releases, grant claims, cancels, and snapshot pool traffic
    (insert / restore-lookup / drop).  After EVERY op: the conservation
    invariant ``free + granted + escrow + snapshot_units == budget`` holds
    and no grant ever carries more units than were requested."""
    clock = itertools.count(1)
    broker = HostMemoryBroker(budget, async_reclaim=True,
                              clock=lambda: float(next(clock)),
                              snapshot_pool_units=budget // 2)
    rids = [f"v{i}" for i in range(n_replicas)]
    order_q = {r: deque() for r in rids}
    grants = {r: [] for r in rids}
    per_replica = budget // (n_replicas + 1)     # leave some pool free
    for i, r in enumerate(rids):
        broker.register(r, per_replica, load=lambda i=i: i,
                        order_sink=order_q[r].append, mode="hotmem")
    broker.check_invariants()

    def front_open(r):
        q = order_q[r]
        while q and not q[0].open:
            q.popleft()
        return q[0] if q else None

    for kind, a, b in ops:
        r = rids[a % n_replicas]
        if kind == "request":
            g = broker.request_grant(r, 1 + b % 8)
            if not g.done or g.available:
                grants[r].append(g)
        elif kind == "drain":
            o = front_open(r)
            if o is not None:
                broker.fulfill_order(o.order_id, 1 + b % 4)
        elif kind == "release":
            have = broker.granted[r]
            if have:
                broker.release_units(r, 1 + b % have)
        elif kind == "claim":
            for g in grants[r]:
                broker.claim_grant(g)
        elif kind == "cancel":
            o = front_open(r)
            if o is not None:
                broker.cancel_order(o.order_id)
        elif kind == "snap_put":
            broker.snapshot_put(f"k{b % 4}", units=1 + b % 4,
                                nbytes=64 * (1 + b % 4), replica_id=r)
        elif kind == "snap_get":
            broker.snapshot_lookup(f"k{b % 4}")
        elif kind == "snap_drop":
            broker.snapshot_drop(f"k{b % 4}")
        broker.check_invariants()                # conservation, every event
        for glist in grants.values():
            for g in glist:
                assert g.fulfilled <= g.requested, \
                    "granted more than requested"
                assert g.pending >= 0 and g.available >= 0
    return broker


def _seeded_broker_ops(seed, n_ops):
    rng = random.Random(seed)
    return [(rng.choice(BROKER_OP_KINDS), rng.randint(0, 15),
             rng.randint(0, 15)) for _ in range(n_ops)]


def run_ledger_ops(ops, budget=32, n_replicas=3):
    """Interpret an op stream directly against ``BudgetLedger`` — the
    extracted conservation core the broker (and every fleet host) now
    delegates to.  Arbitrary legal interleavings of grant fills, unplug
    releases, escrow flows, and snapshot charges keep

        free + sum(granted) + escrow + snapshot == budget

    after EVERY op (``check`` is the broker-independent code path)."""
    led = BudgetLedger(budget)
    rids = [f"r{i}" for i in range(n_replicas)]
    for r in rids:
        led.carve(r, budget // (2 * n_replicas))
    led.check()
    for kind, a, b in ops:
        rid = rids[a % n_replicas]
        if kind == "take":
            got = led.take_free(rid, b % 8)
            assert 0 <= got <= b % 8           # clipped, never overdrafts
        elif kind == "release":
            have = led.granted[rid]
            if have:
                led.release(rid, 1 + b % have)
        elif kind == "escrow_in":
            have = led.granted[rid]
            if have:
                led.escrow_fill(rid, 1 + b % have)
        elif kind == "escrow_out":
            if led.escrow_units:
                led.escrow_claim(rid, 1 + b % led.escrow_units)
        elif kind == "snap_charge":
            if led.free_units:
                led.snapshot_charge(1 + b % led.free_units)
        elif kind == "snap_credit":
            if led.snapshot_units:
                led.snapshot_credit(1 + b % led.snapshot_units)
        led.check()                            # conservation, every event
    return led


def _seeded_ledger_ops(seed, n_ops):
    rng = random.Random(seed)
    return [(rng.choice(LEDGER_OP_KINDS), rng.randint(0, 15),
             rng.randint(0, 15)) for _ in range(n_ops)]


TENANT_LEDGER_OP_KINDS = ("take", "release", "escrow_in", "escrow_out",
                          "snap_charge", "snap_credit")

TENANT_FLEET_OP_KINDS = ("request", "drain", "release", "claim", "cancel",
                         "snap_put", "snap_drop", "migrate")


def run_tenant_ledger_ops(ops, budget=24, tenants=("a", "b", "c")):
    """Interpret an op stream against a MULTI-TENANT ``BudgetLedger``:
    grants overdrawing into host slack, cross-tenant escrow attribution
    (the requester's tenant owns the fill), per-tenant snapshot
    charges/credits.  After EVERY op ``check`` proves the per-tenant
    extension of the conservation law —

        sum_t(free_t + granted_t + escrow_t + snapshot_t) == budget

    with the tenant accounts summing exactly to the host accounts."""
    split = {t: budget // len(tenants) for t in tenants}
    split[tenants[0]] += budget - sum(split.values())
    led = BudgetLedger(budget, tenants=split)
    rids = [f"r_{t}" for t in tenants]
    for t, r in zip(tenants, rids):
        led.carve(r, split[t] // 2, tenant=t)
    led.check()
    for kind, a, b in ops:
        rid = rids[a % len(rids)]
        t = led.tenant_of[rid]
        if kind == "take":
            got = led.take_free(rid, b % 8)
            assert 0 <= got <= b % 8
        elif kind == "release":
            have = led.granted[rid]
            if have:
                led.release(rid, 1 + b % have)
        elif kind == "escrow_in":
            have = led.granted[rid]
            if have:                     # requester = ANOTHER tenant's rid
                led.escrow_fill(rid, 1 + b % have,
                                requester=rids[(a + 1) % len(rids)])
        elif kind == "escrow_out":
            own = led.tenant_escrow(t)
            if own:                      # claims bounded by OWN escrow
                led.escrow_claim(rid, 1 + b % own)
        elif kind == "snap_charge":
            if led.free_units:
                led.snapshot_charge(1 + b % led.free_units, tenant=t)
        elif kind == "snap_credit":
            own = led.tenant_snapshot(t)
            if own:                      # credits bounded by OWN charge
                led.snapshot_credit(1 + b % own, tenant=t)
        led.check()                      # tenant conservation, every event
        assert sum(led.tenant_free(x) + led.tenant_granted(x)
                   + led.tenant_escrow(x) + led.tenant_snapshot(x)
                   for x in led.sub_budgets) == led.budget_units
    return led


def run_tenant_fleet_ops(ops, budget=16, pool_units=6):
    """Interpret an op stream against a 2-host ``FleetScheduler`` whose
    brokers split each budget between two tenants: arbitrary
    interleavings of multi-tenant grants (squeezing only down to other
    tenants' sub-budgets), order drains/cancels, snapshot traffic, and
    cross-host migrations.  After EVERY op each host's ledger re-proves
    the per-tenant conservation law; migrations must never change any
    entry's owner tenant."""
    from repro.cluster import FleetScheduler

    clock = itertools.count(1)
    tenants = {"t0": budget // 2, "t1": budget - budget // 2}
    sched = FleetScheduler()
    hosts = ("h0", "h1")
    order_q = {}
    grants = {}
    for h in hosts:
        b = HostMemoryBroker(budget, async_reclaim=True,
                             clock=lambda: float(next(clock)),
                             snapshot_pool_units=pool_units,
                             tenants=dict(tenants))
        sched.add_host(h, b)
        for i, t in enumerate(sorted(tenants)):
            r = f"{h}/{t}"
            order_q[r] = deque()
            grants[r] = []
            b.register(r, 2, load=lambda i=i: i,
                       order_sink=order_q[r].append, mode="model",
                       tenant=t)
    sched.check_invariants()
    rids = sorted(order_q)

    def front_open(r):
        q = order_q[r]
        while q and not q[0].open:
            q.popleft()
        return q[0] if q else None

    for kind, a, b_arg in ops:
        r = rids[a % len(rids)]
        h = r.split("/")[0]
        broker = sched.brokers[h]
        if kind == "request":
            g = broker.request_grant(r, 1 + b_arg % 6)
            if not g.done or g.available:
                grants[r].append(g)
        elif kind == "drain":
            o = front_open(r)
            if o is not None:
                broker.fulfill_order(o.order_id, 1 + b_arg % 3)
        elif kind == "release":
            have = broker.granted[r]
            if have:
                broker.release_units(r, 1 + b_arg % have)
        elif kind == "claim":
            for g in grants[r]:
                broker.claim_grant(g)
        elif kind == "cancel":
            o = front_open(r)
            if o is not None:
                broker.cancel_order(o.order_id)
        elif kind == "snap_put":
            key = f"k{b_arg % 3}"
            broker.snapshot_put(key, units=1 + b_arg % 2,
                                payload=("kv", key),
                                nbytes=64, replica_id=r)
        elif kind == "snap_drop":
            broker.snapshot_drop(f"k{b_arg % 3}")
        elif kind == "migrate":
            key = f"k{b_arg % 3}"
            src = sched.snapshot_host(key)
            owner = None
            if src is not None:
                owner = sched.brokers[src].snapshots.peek(key).tenant
            rec = sched.ensure_local(key, h)
            if rec is not None:          # owner tenant travelled intact
                assert sched.brokers[h].snapshots.peek(key).tenant \
                    == owner
        for hh in hosts:                 # tenant conservation, every event
            sched.brokers[hh].ledger.check()
        for glist in grants.values():
            for g in glist:
                assert g.fulfilled <= g.requested
    sched.check_invariants()
    for hh in hosts:
        sched.brokers[hh].check_invariants()
        led = sched.brokers[hh].ledger
        # the squeeze fairness rule held throughout: no squeeze of
        # another tenant's entry left that owner below its sub-budget
        # (per-event enforcement is broker-side; here we re-prove the
        # final attribution totals partition the budget)
        assert sum(led.tenant_free(t) + led.tenant_granted(t)
                   + led.tenant_escrow(t) + led.tenant_snapshot(t)
                   for t in led.sub_budgets) == led.budget_units
    return sched


def _seeded_tenant_ops(seed, n_ops, kinds):
    rng = random.Random(seed)
    return [(rng.choice(kinds), rng.randint(0, 15), rng.randint(0, 15))
            for _ in range(n_ops)]


SNAP_ROOM_OP_KINDS = ("room_put", "request", "drain", "release", "claim",
                      "cancel", "drop")


def run_snapshot_room_put_ops(ops, devices=1, rows=12, pool_rows=5):
    """``snapshot_room`` / ``snapshot_put`` agreement under interleaved
    multi-tenant schedules on a ``devices``-wide host: whenever an engine
    asks "would this snapshot fit?" and then immediately inserts it, the
    two answers MUST coincide — room promising space that put then denies
    would strand a paid copy-out; put succeeding where room said no would
    skip captures the pool could hold.  The stream interleaves sharded
    and fragment-less inserts (per-device striped charges), grants with
    partial per-shard drains, releases, claims, cancels, and drops; the
    conservation law is re-proved after every op."""
    from repro.cluster import DeviceTopology

    clock = itertools.count(1)
    n = devices
    budget = rows * n
    tenants = {"t0": budget // 2, "t1": budget - budget // 2}
    broker = HostMemoryBroker(
        async_reclaim=True, clock=lambda: float(next(clock)),
        snapshot_pool_units=pool_rows * n, tenants=tenants,
        topology=DeviceTopology.uniform(budget, n))
    rids = ["r_t0", "r_t1"]
    tenant_of = dict(zip(rids, ("t0", "t1")))
    order_q = {r: deque() for r in rids}
    grants = {r: [] for r in rids}
    for i, r in enumerate(rids):
        broker.register(r, 2 * n, load=lambda i=i: i,
                        order_sink=order_q[r].append, mode="hotmem",
                        tenant=tenant_of[r], shards=n)
    broker.check_invariants()

    def front_open(r):
        q = order_q[r]
        while q and not q[0].open:
            q.popleft()
        return q[0] if q else None

    agreements = 0
    for kind, a, b in ops:
        r = rids[a % len(rids)]
        t = tenant_of[r]
        if kind == "room_put":
            key = f"k{b % 4}"
            units = (1 + b % 3) * n
            frags = tuple(("kv", key, d) for d in range(n)) \
                if n > 1 and b % 2 else None
            room = broker.snapshot_room(key, units, tenant=t)
            ok = broker.snapshot_put(key, units=units,
                                     payload=("kv", key), nbytes=64,
                                     replica_id=r, tenant=t,
                                     fragments=frags)
            assert room == ok, \
                f"room said {room} but put said {ok} for {key}"
            agreements += 1
        elif kind == "request":
            g = broker.request_grant(r, (1 + b % 4) * n)
            if not g.done or g.available:
                grants[r].append(g)
        elif kind == "drain":
            o = front_open(r)
            if o is not None:
                if n == 1:
                    broker.fulfill_order(o.order_id, 1 + b % 3)
                else:                       # partial stripe: SOME shards
                    for d in range(1 + b % n):
                        broker.fulfill_order(o.order_id, 1, shard=d)
        elif kind == "release":
            cov = min(broker.ledger.granted_dev(r))
            if cov:
                broker.release_units(r, (1 + b % cov) * n)
        elif kind == "claim":
            for g in grants[r]:
                broker.claim_grant(g)
        elif kind == "cancel":
            o = front_open(r)
            if o is not None:
                broker.cancel_order(o.order_id)
        elif kind == "drop":
            broker.snapshot_drop(f"k{b % 4}")
        broker.check_invariants()           # conservation, every event
    return broker, agreements


PAGED_SNAP_OP_KINDS = ("put", "restore", "drop", "request", "drain",
                       "release", "claim")


def run_paged_snapshot_ops(ops, devices=1, rows=16, pool_rows=8):
    """Content-addressed pool under interleaved put/restore/evict of
    OVERLAPPING manifests across two tenants on a ``devices``-wide host:
    every manifest draws 1-3 pages from a 4-digest shared pool plus a
    per-key tail page, so puts alias pages across keys and tenants,
    drops deref pages other manifests still hold, and grant pressure
    squeezes entries whose pages stay referenced.  After EVERY op the
    broker re-proves conservation over UNIQUE pages (the ledger's
    snapshot account == store charge, refcounts exactly the live
    manifests' references — never negative), so evicting a shared page
    neither strands nor double-releases its charge."""
    from repro.cluster import DeviceTopology

    clock = itertools.count(1)
    n = devices
    budget = rows * n
    tenants = {"t0": budget // 2, "t1": budget - budget // 2}
    broker = HostMemoryBroker(
        async_reclaim=True, clock=lambda: float(next(clock)),
        snapshot_pool_units=pool_rows * n, tenants=tenants,
        topology=DeviceTopology.uniform(budget, n))
    rids = ["r_t0", "r_t1"]
    tenant_of = dict(zip(rids, ("t0", "t1")))
    order_q = {r: deque() for r in rids}
    grants = {r: [] for r in rids}
    for i, r in enumerate(rids):
        broker.register(r, 2 * n, load=lambda i=i: i,
                        order_sink=order_q[r].append, mode="hotmem",
                        tenant=tenant_of[r], shards=n)
    broker.check_invariants()

    def front_open(r):
        q = order_q[r]
        while q and not q[0].open:
            q.popleft()
        return q[0] if q else None

    def pages_for(ki, salt):
        """1-3 pages from the shared digest pool + a per-key tail; the
        same digest always carries the same units/bytes/payload (the
        content IS the identity), striped over the mesh."""
        picks = [(salt + j) % 3 for j in range(1 + salt % 3)]
        pgs = [(f"s{p}.d{n}", n, 32, ("pg", "s", p)) for p in picks]
        pgs.append((f"t{ki}.d{n}", n, 16, ("pg", "t", ki)))
        return pgs

    puts = shared_seen = 0
    for kind, a, b in ops:
        r = rids[a % len(rids)]
        t = tenant_of[r]
        if kind == "put":
            key = f"k{b % 4}"
            pgs = pages_for(b % 4, a + b)
            units = sum(u for _d, u, _nb, _pl in pgs)
            room = broker.snapshot_room(key, units, tenant=t, pages=pgs)
            ok = broker.snapshot_put(
                key, units=units, payload=("kv", key),
                nbytes=sum(nb for _d, _u, nb, _pl in pgs),
                replica_id=r, tenant=t, pages=pgs)
            assert room == ok, \
                f"room said {room} but put said {ok} for {key}"
            puts += ok
        elif kind == "restore":
            key = f"k{b % 4}"
            snap = broker.snapshot_lookup(key)
            if snap is not None and snap.pages is not None:
                specs = broker.snapshot_page_specs(key)
                # the manifest resolves completely, in order, and its
                # page units sum back to the entry's charge
                assert [d for d, _u, _nb, _pl in specs] == list(snap.pages)
                assert sum(u for _d, u, _nb, _pl in specs) == snap.units
                assert broker.missing_pages(list(snap.pages)) == []
        elif kind == "drop":
            broker.snapshot_drop(f"k{b % 4}")
        elif kind == "request":
            g = broker.request_grant(r, (1 + b % 4) * n)
            if not g.done or g.available:
                grants[r].append(g)
        elif kind == "drain":
            o = front_open(r)
            if o is not None:
                for d in range(1 + b % n) if n > 1 else (0,):
                    broker.fulfill_order(o.order_id, 1, shard=d)
        elif kind == "release":
            cov = min(broker.ledger.granted_dev(r))
            if cov:
                broker.release_units(r, (1 + b % cov) * n)
        elif kind == "claim":
            for g in grants[r]:
                broker.claim_grant(g)
        broker.check_invariants()           # conservation over UNIQUE pages
        pool = broker.snapshots
        paged_units = sum(s.units for s in map(pool.peek, pool.keys())
                          if s.pages is not None)
        assert pool.referenced_units == paged_units
        assert pool.pages.unique_units <= paged_units
        shared_seen += pool.pages.unique_units < paged_units
    return broker, puts, shared_seen


# ------------------------------------------------- hypothesis (if present)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.one_of(
            st.tuples(st.just("reserve"), st.integers(0, 15)),
            st.tuples(st.just("grow"), st.integers(0, 15)),
            st.tuples(st.just("release"), st.integers(0, 15)),
            st.tuples(st.just("fork"), st.integers(0, 15)),
            st.tuples(st.just("plug"), st.integers(1, 4)),
            st.tuples(st.just("unplug"), st.integers(1, 4)),
        ),
        min_size=1, max_size=60,
    )

    @settings(max_examples=200, deadline=None)
    @given(OPS)
    def test_hotmem_invariants(ops):
        run_hotmem_ops(ops)

    @settings(max_examples=200, deadline=None)
    @given(OPS)
    def test_vanilla_invariants(ops):
        run_vanilla_ops(ops)

    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 8), st.integers(0, 8))
    def test_hotmem_unplug_only_free_suffix(n_live, k):
        _check_unplug_only_free_suffix(n_live, k)

    @settings(max_examples=100, deadline=None)
    @given(st.integers(2, 8))
    def test_waitqueue_fifo_wakeup(n):
        _check_waitqueue_fifo(n)

    BROKER_OPS = st.lists(
        st.tuples(st.sampled_from(BROKER_OP_KINDS),
                  st.integers(0, 15), st.integers(0, 15)),
        min_size=1, max_size=80,
    )

    @settings(max_examples=200, deadline=None)
    @given(BROKER_OPS, st.integers(2, 4))
    def test_async_broker_conservation(ops, n_replicas):
        run_async_broker_ops(ops, n_replicas)

    LEDGER_OPS = st.lists(
        st.tuples(st.sampled_from(LEDGER_OP_KINDS),
                  st.integers(0, 15), st.integers(0, 15)),
        min_size=1, max_size=80,
    )

    @settings(max_examples=200, deadline=None)
    @given(LEDGER_OPS, st.integers(2, 4))
    def test_ledger_conservation(ops, n_replicas):
        run_ledger_ops(ops, n_replicas=n_replicas)

    TENANT_LEDGER_OPS = st.lists(
        st.tuples(st.sampled_from(TENANT_LEDGER_OP_KINDS),
                  st.integers(0, 15), st.integers(0, 15)),
        min_size=1, max_size=80,
    )

    @settings(max_examples=200, deadline=None)
    @given(TENANT_LEDGER_OPS)
    def test_tenant_ledger_conservation(ops):
        run_tenant_ledger_ops(ops)

    TENANT_FLEET_OPS = st.lists(
        st.tuples(st.sampled_from(TENANT_FLEET_OP_KINDS),
                  st.integers(0, 15), st.integers(0, 15)),
        min_size=1, max_size=60,
    )

    @settings(max_examples=100, deadline=None)
    @given(TENANT_FLEET_OPS)
    def test_tenant_fleet_conservation(ops):
        run_tenant_fleet_ops(ops)

    SNAP_ROOM_OPS = st.lists(
        st.tuples(st.sampled_from(SNAP_ROOM_OP_KINDS),
                  st.integers(0, 15), st.integers(0, 15)),
        min_size=1, max_size=70,
    )

    @settings(max_examples=100, deadline=None)
    @given(SNAP_ROOM_OPS, st.sampled_from([1, 2, 4]))
    def test_snapshot_room_put_agreement(ops, devices):
        run_snapshot_room_put_ops(ops, devices=devices)

    PAGED_SNAP_OPS = st.lists(
        st.tuples(st.sampled_from(PAGED_SNAP_OP_KINDS),
                  st.integers(0, 15), st.integers(0, 15)),
        min_size=1, max_size=70,
    )

    @settings(max_examples=100, deadline=None)
    @given(PAGED_SNAP_OPS, st.sampled_from([1, 2, 4]))
    def test_paged_snapshot_conservation(ops, devices):
        run_paged_snapshot_ops(ops, devices=devices)
else:
    def test_hypothesis_missing_is_reported():
        """Collection must stay green without hypothesis; the seeded
        fallback below carries the invariant coverage."""
        pytest.importorskip("hypothesis")


# ------------------------------------------------ seeded pytest fallback


@pytest.mark.parametrize("seed", range(25))
def test_hotmem_invariants_seeded(seed):
    run_hotmem_ops(_seeded_ops(seed, 60))


@pytest.mark.parametrize("seed", range(25))
def test_vanilla_invariants_seeded(seed):
    run_vanilla_ops(_seeded_ops(1000 + seed, 60))


@pytest.mark.parametrize("seed", range(25))
@pytest.mark.parametrize("n_replicas", [2, 3, 4])
def test_async_broker_conservation_seeded(seed, n_replicas):
    run_async_broker_ops(_seeded_broker_ops(2000 + seed, 80), n_replicas)


@pytest.mark.parametrize("seed", range(25))
@pytest.mark.parametrize("n_replicas", [2, 3, 4])
def test_ledger_conservation_seeded(seed, n_replicas):
    run_ledger_ops(_seeded_ledger_ops(3000 + seed, 80),
                   n_replicas=n_replicas)


@pytest.mark.parametrize("seed", range(25))
def test_tenant_ledger_conservation_seeded(seed):
    run_tenant_ledger_ops(
        _seeded_tenant_ops(4000 + seed, 80, TENANT_LEDGER_OP_KINDS))


@pytest.mark.parametrize("seed", range(20))
def test_tenant_fleet_conservation_seeded(seed):
    run_tenant_fleet_ops(
        _seeded_tenant_ops(5000 + seed, 60, TENANT_FLEET_OP_KINDS))


@pytest.mark.parametrize("seed", range(20))
@pytest.mark.parametrize("devices", [1, 2, 4])
def test_snapshot_room_put_agreement_seeded(seed, devices):
    _, agreements = run_snapshot_room_put_ops(
        _seeded_tenant_ops(6000 + seed, 70, SNAP_ROOM_OP_KINDS),
        devices=devices)
    assert agreements > 0                  # the property was exercised


@pytest.mark.parametrize("seed", range(20))
@pytest.mark.parametrize("devices", [1, 2, 4])
def test_paged_snapshot_conservation_seeded(seed, devices):
    _, puts, _ = run_paged_snapshot_ops(
        _seeded_tenant_ops(7000 + seed, 70, PAGED_SNAP_OP_KINDS),
        devices=devices)
    assert puts > 0                        # manifests actually landed


@pytest.mark.parametrize("devices", [1, 2, 4])
def test_paged_snapshot_sharing_exercised(devices):
    """A scripted walk where two tenants' manifests provably alias a
    page (both include digest ``s0``): the interpreter's per-op checks
    then cover exactly the shared-page deref path — dropping either
    manifest must leave the other restorable with its charge intact."""
    ops = [("put", 0, 0),        # t0: k0 = [s0] + tail
           ("put", 1, 1),        # t1: k1 = [s2, s0, s1] + tail — aliases s0
           ("restore", 0, 0), ("restore", 0, 1),
           ("drop", 0, 0),       # deref shared s0; k1 keeps it alive
           ("restore", 0, 1),
           ("drop", 0, 1)]       # refcount to zero: charge fully released
    broker, puts, shared_seen = run_paged_snapshot_ops(ops,
                                                       devices=devices)
    assert puts == 2 and shared_seen > 0
    assert broker.snapshot_units() == 0    # nothing stranded at the end


def test_tenant_ledger_scripted_flows_and_guards():
    """Exact-arithmetic walk through the per-tenant extension: overdrawn
    tenant_free, cross-tenant escrow attribution (the requester's grant
    owns the fill), per-tenant snapshot accounts — plus the loud guards:
    a tenant cannot claim escrow or credit snapshot units it does not
    own, and sub-budgets must partition the budget exactly."""
    led = BudgetLedger(16, tenants={"a": 10, "b": 6})
    led.carve("ra", 4, tenant="a")
    led.carve("rb", 3, tenant="b")               # free 9
    led.check()
    assert led.take_free("ra", 5) == 5           # a granted 9, free 4
    assert led.take_free("rb", 4) == 4           # b granted 7, free 0
    assert led.tenant_free("a") == 1
    assert led.tenant_free("b") == -1            # overdrawn into a's slack
    led.check()                                  # sum of frees == 0 == free
    # escrow attribution: rb requests, ra drains -> tenant b owns it
    led.escrow_fill("ra", 2, requester="rb")
    assert led.tenant_escrow("b") == 2 and led.tenant_escrow("a") == 0
    assert led.tenant_usage("a") == 7 and led.tenant_usage("b") == 9
    with pytest.raises(AssertionError):
        led.escrow_claim("ra", 1)                # a owns no escrow
    led.escrow_claim("rb", 2)                    # b granted 9
    assert led.tenant_escrow("b") == 0
    led.check()
    # per-tenant snapshot accounts
    led.release("ra", 4)                         # free 4, a granted 3
    led.snapshot_charge(2, tenant="a")
    led.snapshot_charge(1, tenant="b")
    assert led.tenant_snapshot("a") == 2 and led.tenant_snapshot("b") == 1
    with pytest.raises(AssertionError):
        led.snapshot_credit(2, tenant="b")       # b owns only 1
    led.snapshot_credit(2, tenant="a")
    led.snapshot_credit(1, tenant="b")
    led.check()
    assert led.tenant_usage("a") == 3 and led.tenant_usage("b") == 9
    rep = led.tenant_report()
    assert rep["b"]["free"] == -3 and rep["a"]["free"] == 7
    # constructor and resolution guards
    with pytest.raises(AssertionError):
        BudgetLedger(16, tenants={"a": 10, "b": 5})   # does not sum
    with pytest.raises(AssertionError):
        led.carve("rc", 1, tenant="nope")             # unknown tenant
    with pytest.raises(AssertionError):
        led.resolve_tenant(None)                      # ambiguous on multi
    led.check()                                       # guards mutated nothing


def test_ledger_scripted_flows_and_overdraft_guards():
    """Exact-arithmetic walk through every ledger verb, plus the loud
    failures: each account rejects an overdraft AT the flow (so a leak
    is attributed to the illegal move, not discovered later)."""
    led = BudgetLedger(16)
    led.carve("a", 4)
    led.carve("b", 4)                          # free 8
    led.check()
    assert led.take_free("a", 5) == 5          # free 3, a=9
    assert led.take_free("b", 9) == 3          # clipped to the pool
    led.check()
    assert led.free_units == 0 and led.granted == {"a": 9, "b": 7}
    led.escrow_fill("b", 2)                    # b=5, escrow 2
    led.escrow_claim("a", 2)                   # a=11, escrow 0
    led.release("a", 6)                        # free 6
    led.snapshot_charge(5)                     # free 1, snapshot 5
    led.snapshot_credit(0)                     # explicit no-op
    led.snapshot_credit(5)                     # free 6, snapshot 0
    led.check()
    assert led.free_units == 6
    assert led.granted == {"a": 5, "b": 5}
    assert led.escrow_units == 0 and led.snapshot_units == 0
    # overdraft guards, one per account
    with pytest.raises(AssertionError):
        led.carve("a", 1)                      # double boot
    with pytest.raises(AssertionError):
        led.carve("c", 7)                      # beyond the free pool
    with pytest.raises(AssertionError):
        led.release("a", 6)                    # more than granted
    with pytest.raises(AssertionError):
        led.escrow_fill("a", 6)                # more than the victim holds
    with pytest.raises(AssertionError):
        led.escrow_claim("a", 1)               # empty escrow
    with pytest.raises(AssertionError):
        led.snapshot_charge(7)                 # beyond the free pool
    with pytest.raises(AssertionError):
        led.snapshot_credit(1)                 # empty pool charge
    with pytest.raises(AssertionError):
        led.take_free("nope", 1)               # unregistered replica
    led.check()                                # guards mutated nothing


def _check_unplug_only_free_suffix(n_live, k):
    """Unplug must never touch a live partition (zero-migration is only
    possible because shrink takes empty partitions exclusively)."""
    m = HotMemManager(SPEC)
    rids = [f"r{i}" for i in range(n_live)]
    for r in rids:
        m.reserve(r)
    owned = {m.partition_of(r) for r in rids}
    ev = m.unplug(k)
    assert ev.reclaimed_units <= SPEC.n_partitions - n_live
    for r in rids:
        assert m.partition_of(r) in owned
    m.check_invariants()


def _check_waitqueue_fifo(n):
    m = HotMemManager(SPEC, plugged=1)
    assert m.reserve("holder") is not None
    for i in range(n):
        assert m.reserve(f"w{i}") is None
    woken = m.release("holder")
    assert woken == "w0"                    # FIFO
    assert list(m.waitqueue) == [f"w{i}" for i in range(1, n)]


@pytest.mark.parametrize("n_live,k", [(n, k) for n in range(1, 9)
                                      for k in (0, 2, 4, 8)])
def test_unplug_only_free_suffix_seeded(n_live, k):
    _check_unplug_only_free_suffix(n_live, k)


@pytest.mark.parametrize("n", range(2, 9))
def test_waitqueue_fifo_wakeup_seeded(n):
    _check_waitqueue_fifo(n)
