"""Elastic data-plane tests: migrations preserve content; HotMem shrink is
a pure prefix truncation; plug zero-fills exactly the new rows."""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.arena import ArenaSpec
from repro.core.elastic import (ElasticArena, apply_migrations,
                                bucket_ladder, slice_rows, target_bucket,
                                zero_rows)


def _spec():
    cfg = reduced(get_config("qwen2-7b"))
    return cfg, ArenaSpec.from_model(cfg, partition_tokens=64,
                                     n_partitions=8, block_tokens=16)


def test_bucket_ladder():
    assert bucket_ladder(64, 2) == [2, 4, 8, 16, 32, 64]
    lad = bucket_ladder(64, 2)
    assert target_bucket(lad, 3) == 4
    assert target_bucket(lad, 64) == 64
    assert target_bucket(lad, 65) == 64


def test_apply_migrations_content():
    pool = {"k": jnp.arange(16 * 4, dtype=jnp.float32).reshape(16, 4)}
    src = jnp.asarray([15, 14, 0, 0], jnp.int32)
    dst = jnp.asarray([1, 3, 0, 0], jnp.int32)
    out = apply_migrations(pool, src, dst, jnp.asarray(2))
    np.testing.assert_array_equal(out["k"][1], pool["k"][15])
    np.testing.assert_array_equal(out["k"][3], pool["k"][14])
    np.testing.assert_array_equal(out["k"][2], pool["k"][2])  # untouched


def test_zero_rows_range_only():
    c = {"k": jnp.ones((8, 4))}
    out = zero_rows(c, jnp.asarray(5), jnp.asarray(2))
    assert float(out["k"][:5].sum()) == 20.0
    assert float(out["k"][5:7].sum()) == 0.0
    assert float(out["k"][7].sum()) == 4.0


def test_vanilla_unplug_grows_with_occupancy():
    """Paper Fig. 6: migration volume rises with occupancy; HotMem stays
    at zero regardless."""
    cfg, spec = _spec()
    results = []
    for n_live in (1, 3, 5):
        va = ElasticArena(cfg, spec, "vanilla", seed=2)
        for i in range(n_live):
            va.admit(f"r{i}")
            va.on_tokens(f"r{i}", 64)
        k, moves = va.manager.shrink_plan(8)
        results.append(len(moves))
        hm = ElasticArena(cfg, spec, "hotmem")
        for i in range(n_live):
            hm.admit(f"h{i}")
            hm.on_tokens(f"h{i}", 64)
        ev = hm.unplug(2)
        assert ev.migrated_bytes == 0
    assert results[0] <= results[-1]
    assert results[-1] > 0


def test_hotmem_shrink_is_prefix_slice():
    caches = {"k": jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)}
    out = slice_rows(caches, 5)
    np.testing.assert_array_equal(out["k"], caches["k"][:5])
