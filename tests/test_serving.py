"""Serving-engine integration: the paper's behavioural claims at system
level (C1/C4: fast zero-migration reclaim; C5: P99 parity with static
over-provisioning; budget kills; warm starts skip prefill)."""
import jax
import pytest

from repro.configs.base import get_config, reduced
from repro.core.arena import ArenaSpec
from repro.models import model as M
from repro.serving.engine import ServeEngine
from repro.serving.request import PROFILES, FunctionProfile, Request, State
from repro.serving.tracegen import assign_profiles, bursty_trace


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    return cfg, params, spec


def _trace(seed=3, duration=16.0):
    arr = bursty_trace(duration, 0.8, burst_x=6, burst_at=(0.0,),
                       burst_len=3.0, quiet_after=duration / 2, seed=seed)
    return assign_profiles(arr, PROFILES, seed)


@pytest.mark.parametrize("mode", ["hotmem", "vanilla", "static"])
def test_trace_completes(setup, mode):
    cfg, params, spec = setup
    reqs = [Request(rid=f"{mode}{i}", profile=p, submit_s=t)
            for i, (t, p) in enumerate(_trace())]
    eng = ServeEngine(cfg, params, spec, mode=mode, keep_alive=3.0)
    m = eng.run(reqs, max_virtual_s=2000)
    assert m["completed"] == len(reqs)
    assert m["killed"] == 0
    if mode == "hotmem":
        assert m["migrated_bytes"] == 0          # C1: zero migration
        eng.arena.manager.check_invariants()
    if mode == "vanilla":
        eng.arena.manager.check_invariants()
    if mode != "static":
        assert m["reclaimed_bytes"] > 0          # elasticity engaged


def test_budget_kill(setup):
    """Exceeding the declared budget triggers the OOM-kill analogue."""
    cfg, params, spec = setup
    greedy = FunctionProfile("greedy", prompt_tokens=8, decode_tokens=400,
                             max_tokens=spec.partition_tokens * 4)
    eng = ServeEngine(cfg, params, spec, mode="hotmem")
    eng.run([Request(rid="g", profile=greedy, submit_s=0.0)],
            max_virtual_s=500)
    assert eng.arena.manager.kills == 1
    assert eng.done[0].state is State.KILLED


def test_warm_start_skips_prefill(setup):
    cfg, params, spec = setup
    prof = PROFILES["cnn"]
    # b arrives long after a completes but inside the keep-alive window
    reqs = [Request(rid="a", profile=prof, submit_s=0.0),
            Request(rid="b", profile=prof, submit_s=100.0)]
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=1000.0)
    eng.run(reqs, max_virtual_s=5000)
    prefills = [e for e in eng.events if e.kind == "prefill"]
    assert len(prefills) == 1                    # b reused a's partition


def test_waitqueue_admission(setup):
    cfg, params, spec = setup
    import dataclasses
    tiny = dataclasses.replace(spec, n_partitions=2)
    prof = PROFILES["cnn"]
    reqs = [Request(rid=f"q{i}", profile=prof, submit_s=0.0)
            for i in range(5)]
    eng = ServeEngine(cfg, params, tiny, mode="static", keep_alive=0.0)
    m = eng.run(reqs, max_virtual_s=2000)
    assert m["completed"] == 5                   # all served eventually
