"""Content-addressed snapshot store (PR 9): refcounted ledger charge,
copy-on-write restore, dedup-aware migration.

The properties pinned down:

  (a) ``PageStore`` refcounts: a page charges on FIRST reference only
      (the referencing tenant becomes owner), dedup hits are free,
      deref returns exactly the ledger flow the broker must apply
      (freed / reattributed-to-min-surviving-tenant / shared), and a
      digest collision with different content fails loudly;
  (b) broker walks: overlapping manifests across tenants charge unique
      units once, dropping the owner's entry REATTRIBUTES the shared
      page's charge instead of stranding or double-releasing it, and
      squeezing an entry whose pages another manifest still references
      frees only the newly-unreferenced units — conservation re-proved
      after every event;
  (c) migration moves only the pages the destination LACKS: a second
      manifest sharing pages with one already migrated pays only its
      tail, a fully-shared manifest moves zero bytes (no transfer, no
      contention), and the unpaged path still moves the full payload;
  (d) ``page_size=None`` is the legacy pool bit-exactly: an unpaged
      scenario row replays byte-identically against the committed
      ``BENCH_6.json`` baseline, and the dedup scenario family shows
      unique units <= 50% of the duplicated baseline with strictly
      fewer migrated bytes and warm < restore < cold TTFT;
  (e) (slow) a real ``ServeEngine`` captures page manifests, restores
      reassemble them bit-exactly, and a restore whose pages are
      already mapped pays only the copy wall (CoW) — strictly below
      the same restore on a replica that has never seen the pages.
"""
import itertools
import json
import os
from collections import deque

import pytest

from repro.cluster import FleetScheduler, HostMemoryBroker
from repro.cluster.snapshots import PageStore
from repro.core.arena import ArenaSpec
from repro.serving.request import PROFILES, Request

from conftest import fake_clock as _fake_clock

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks")


# --------------------------------------------------- (a) PageStore flows


def test_page_charges_once_and_owner_is_first_referencing_tenant():
    s = PageStore()
    assert s.ref("A", units=2, nbytes=64, payload=("pg", 0), tenant="t0")
    assert not s.ref("A", units=2, nbytes=64, payload=("pg", 0),
                     tenant="t1")            # dedup hit: no ledger flow
    assert s.dedup_hits == 1
    assert s.unique_units == 2 and len(s) == 1
    assert s.get("A").owner == "t0" and s.get("A").refs == 2
    assert s.owner_units() == {"t0": 2}
    s.check_invariants()
    # non-owner deref: page stays, still owned and charged to t0
    assert s.deref("A", "t1") == ("shared", 0, "", "")
    # last deref frees: credit the OWNER, not the last dereferencer
    assert s.deref("A", "t0") == ("freed", 2, "t0", "")
    assert "A" not in s and s.unique_units == 0
    s.check_invariants()


def test_owner_deref_reattributes_to_min_surviving_tenant():
    s = PageStore()
    s.ref("A", units=3, nbytes=64, payload=("pg", 0), tenant="t1")
    s.ref("A", units=3, nbytes=64, payload=("pg", 0), tenant="t2")
    s.ref("A", units=3, nbytes=64, payload=("pg", 0), tenant="t0")
    # owner t1's last reference drops while t0/t2 still hold the page:
    # the charge moves deterministically to min(surviving) == t0
    assert s.deref("A", "t1") == ("reattributed", 3, "t1", "t0")
    assert s.get("A").owner == "t0"
    s.check_invariants()
    assert s.deref("A", "t2") == ("shared", 0, "", "")
    assert s.deref("A", "t0") == ("freed", 3, "t0", "")


def test_digest_collision_and_foreign_deref_fail_loudly():
    s = PageStore()
    s.ref("A", units=1, nbytes=64, payload=("pg", 0), tenant="t0")
    with pytest.raises(AssertionError, match="collision"):
        s.ref("A", units=2, nbytes=64, payload=("pg", 0), tenant="t0")
    with pytest.raises(AssertionError, match="non-referencing"):
        s.deref("A", "t9")
    s.check_invariants()                     # failed ops mutated nothing


def test_missing_preserves_order_and_collapses_duplicates():
    s = PageStore()
    s.ref("B", units=1, nbytes=8, payload=("pg", 1), tenant="t0")
    assert s.missing(["C", "B", "A", "C", "A"]) == ["C", "A"]
    assert s.missing(["B"]) == []


# ------------------------------------------- (b) broker conservation walks


def _mk_paged_broker(budget=16, pool=8, tenants=None):
    clock = itertools.count(1)
    return HostMemoryBroker(budget, clock=lambda: float(next(clock)),
                            snapshot_pool_units=pool,
                            tenants=tenants)


def test_overlapping_manifests_charge_unique_units_once():
    b = _mk_paged_broker(tenants={"t0": 8, "t1": 8})
    pa = ("A", 2, 100, ("pg", "A"))
    pb = ("B", 1, 50, ("pg", "B"))
    pc = ("C", 1, 50, ("pg", "C"))
    assert b.snapshot_put("k0", units=3, pages=[pa, pb], tenant="t0")
    b.check_invariants()
    assert b.snapshot_units() == 3
    assert b.snapshot_put("k1", units=3, pages=[pa, pc], tenant="t1")
    b.check_invariants()
    # A deduped: only C newly charged; manifests still reference 6
    assert b.snapshot_units() == 4
    assert b.snapshots.referenced_units == 6
    assert b.ledger.tenant_snapshot("t0") == 3   # owns A and B
    assert b.ledger.tenant_snapshot("t1") == 1   # owns C only
    # dropping the OWNER's manifest: B freed (credit t0), A reattributed
    # to t1 (still referenced by k1) — nothing stranded, nothing double-
    # released, and k1 stays restorable
    b.snapshot_drop("k0")
    b.check_invariants()
    assert b.snapshot_units() == 3
    assert b.ledger.tenant_snapshot("t0") == 0
    assert b.ledger.tenant_snapshot("t1") == 3
    assert b.snapshot_lookup("k1") is not None
    assert b.missing_pages(["A", "C"]) == []
    b.snapshot_drop("k1")
    b.check_invariants()
    assert b.snapshot_units() == 0 and len(b.snapshots.pages) == 0


def test_squeeze_of_shared_manifest_frees_only_unreferenced_units():
    """Grant pressure squeezes a manifest whose big page another entry
    still references: the squeeze frees only the tail's units (the
    shared page stays charged — once), and the survivor restores."""
    clock = itertools.count(1)
    broker = HostMemoryBroker(12, async_reclaim=True,
                              clock=lambda: float(next(clock)),
                              snapshot_pool_units=8)
    sink = deque()
    broker.register("r", 4, order_sink=sink.append, mode="hotmem",
                    load=lambda: 0)
    shared = ("S", 4, 200, ("pg", "S"))
    assert broker.snapshot_put("k0", units=5,
                               pages=[shared, ("T0", 1, 8, ("pg", 0))])
    assert broker.snapshot_put("k1", units=5,
                               pages=[shared, ("T1", 1, 8, ("pg", 1))])
    broker.check_invariants()
    assert broker.snapshot_units() == 6          # 4 + 1 + 1, S once
    assert broker.free_units == 2
    # deficit 3: free 2 + squeeze.  Dropping BOTH entries only frees 6
    # units total; the plan prices each drop by its NEWLY-unreferenced
    # units (k0 -> 1, then k1 -> 5), never by the referenced sum
    g = broker.request_grant("r", 5)
    broker.check_invariants()
    assert g.granted == 5 and g.done and not sink
    assert broker.snapshot_units() == 0
    assert len(broker.snapshots.pages) == 0


def test_paged_room_probe_agrees_with_put_when_fully_shared():
    """A manifest whose every page is already stored needs zero new
    units: room says yes even with a full free pool, and put charges
    nothing."""
    b = _mk_paged_broker(budget=8, pool=4)
    pg = ("A", 4, 64, ("pg", "A"))
    assert b.snapshot_put("k0", units=4, pages=[pg])
    b.register("r", 4)                           # free pool now 0
    assert b.free_units == 0
    assert b.snapshot_room("k1", 4, pages=[pg])
    assert b.snapshot_put("k1", units=4, pages=[pg])
    b.check_invariants()
    assert b.snapshot_units() == 4               # still one charge
    assert b.snapshots.pages.dedup_hits == 1
    assert b.snapshots.referenced_units == 8


# ------------------------------------------ (c) dedup-aware migration


def _mk_fleet(pool=8, bandwidth=100.0):
    sched = FleetScheduler(bandwidth_bytes_per_s=bandwidth,
                           link_latency_s=0.0, clock=_fake_clock())
    for h in ("h0", "h1"):
        sched.add_host(h, HostMemoryBroker(
            16, clock=_fake_clock(), snapshot_pool_units=pool))
    return sched


def test_migration_moves_only_pages_the_destination_lacks():
    sched = _mk_fleet()
    b0 = sched.brokers["h0"]
    pp = ("P", 1, 100, ("pg", "P"))
    pq = ("Q", 1, 50, ("pg", "Q"))
    pr = ("R", 1, 50, ("pg", "R"))
    assert b0.snapshot_put("k0", units=2, nbytes=150,
                           payload=("kv", 0), pages=[pp, pq])
    assert b0.snapshot_put("k1", units=2, nbytes=150,
                           payload=("kv", 1), pages=[pp, pr])
    rec0 = sched.migrate_snapshot("k0", "h1")    # cold dest: both pages
    assert rec0.nbytes == 150
    assert rec0.copy_seconds == pytest.approx(1.5)
    sched.check_invariants()
    # P already landed with k0 — k1's transfer carries only R (its
    # copy wall still contends with rec0's transfer where they overlap)
    rec1 = sched.migrate_snapshot("k1", "h1")
    assert rec1.nbytes == 50
    assert rec1.copy_seconds < rec0.copy_seconds
    b1 = sched.brokers["h1"]
    assert b1.snapshot_restorable("k0") and b1.snapshot_restorable("k1")
    assert b1.snapshot_units() == 3              # P, Q, R — once each
    b1.check_invariants()
    # the unpaged path still pays full payload bytes for the same size
    assert b0.snapshot_put("k2", units=2, nbytes=150, payload=("kv", 2))
    rec2 = sched.migrate_snapshot("k2", "h1")
    assert rec2.nbytes == 150


def test_fully_shared_manifest_migrates_zero_bytes():
    """Warm state the destination already holds page-for-page moves as
    pure metadata: zero bytes, zero copy wall, no interconnect transfer
    to contend with."""
    sched = _mk_fleet()
    b0 = sched.brokers["h0"]
    pages = [("P", 1, 100, ("pg", "P")), ("Q", 1, 50, ("pg", "Q"))]
    assert b0.snapshot_put("k0", units=2, nbytes=150,
                           payload=("kv", 0), pages=list(pages))
    sched.migrate_snapshot("k0", "h1")
    assert b0.snapshot_put("k3", units=2, nbytes=150,
                           payload=("kv", 3), pages=list(pages))
    before = len(sched._inflight)
    rec = sched.migrate_snapshot("k3", "h1")
    assert rec is not None and rec.nbytes == 0
    assert rec.copy_seconds == 0.0
    assert len(sched._inflight) == before        # nothing on the wire
    assert sched.brokers["h1"].snapshot_restorable("k3")
    sched.check_invariants()


def test_drain_host_migrates_paged_entries_dedup_aware():
    sched = _mk_fleet()
    b0, b1 = sched.brokers["h0"], sched.brokers["h1"]
    pages = [("P", 1, 100, ("pg", "P")), ("Q", 1, 50, ("pg", "Q"))]
    assert b0.snapshot_put("k0", units=2, nbytes=150,
                           payload=("kv", 0), pages=list(pages))
    assert b1.snapshot_put("peer", units=2, nbytes=150,
                           payload=("kv", 9), pages=list(pages))    # dest already holds P, Q
    sched.begin_retire("h0")
    stats = sched.drain_host("h0")
    assert stats == {"migrated": 1, "deferred": 0, "discarded": 0}
    assert sched.migrations[-1].nbytes == 0      # fully shared: metadata
    assert sched.finish_retire("h0")
    assert b1.snapshot_units() == 2              # one charge for P + Q
    b1.check_invariants()


# ----------------------------- (d) unpaged bit-identity + dedup scenarios


def test_unpaged_scenario_row_bit_identical_to_committed_baseline():
    """The ``page_size=None`` regression pin: the refactor must not
    perturb the legacy pool by a single bit, so an unpaged bank row is
    compared FIELD-EXACT (not within regression slack) against the
    committed baseline."""
    from repro.cluster.scenarios import run_scenario
    with open(os.path.join(BENCH_DIR, "BENCH_6.json")) as f:
        base = json.load(f)
    old = base["diurnal_smoke"]
    row = run_scenario("diurnal_smoke", seed=old["seed"])
    assert row == old


def test_dedup_scenario_halves_units_and_migrated_bytes():
    """The acceptance comparison: same trace, same budgets — paged
    capture keeps <= 50% of the duplicated baseline's snapshot charge
    and strictly fewer migrated bytes, with the TTFT ordering
    warm < restore < cold intact."""
    from repro.cluster.scenarios import run_scenario
    paged = run_scenario("dedup_prefix", seed=0)
    flat = run_scenario("dedup_baseline", seed=0)
    assert paged["unique_snapshot_units"] * 2 \
        <= flat["unique_snapshot_units"]
    assert paged["dedup_ratio"] < 1.0 == flat["dedup_ratio"]
    assert paged["migrated_snapshot_bytes"] \
        < flat["migrated_snapshot_bytes"]
    assert 0.0 <= paged["warm_ttft_ms"] < paged["restore_ttft_ms"] \
        < paged["cold_ttft_ms"]
    # the paged run replays bit-identically for a fixed seed
    again = run_scenario("dedup_prefix", seed=0)
    assert json.dumps(paged, sort_keys=True) \
        == json.dumps(again, sort_keys=True)


# --------------------------------------------- (e) engine CoW (slow)


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import model as M
    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    return cfg, params, spec


def _run_one(eng, rid, prof="cnn"):
    eng.submit(Request(rid=rid, profile=PROFILES[prof], submit_s=eng.now))
    empty = deque()
    while eng.active or eng.pending:
        eng._tick(empty)
    return next(r for r in eng.done if r.rid == rid)


def _expire(eng):
    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()


@pytest.mark.slow
def test_engine_paged_capture_and_cow_restore(setup):
    """Capture splits the partition into content pages; a replica that
    already maps every page restores paying ONLY the copy wall, strictly
    below a replica materializing the pages for the first time."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    a = ServeEngine(cfg, params, spec, keep_alive=2.0, seed=0,
                    broker=broker, replica_id="A",
                    snapshot_page_bytes=4096)
    b = ServeEngine(cfg, params, spec, keep_alive=2.0, seed=1,
                    broker=broker, replica_id="B",
                    snapshot_page_bytes=4096)
    _run_one(a, "c0")
    _expire(a)
    broker.check_invariants()
    snap = broker.snapshots.peek("cnn")
    assert snap is not None and snap.pages is not None
    assert len(snap.pages) > 1                   # actually paginated
    specs = broker.snapshot_page_specs("cnn")
    assert [d for d, _u, _nb, _pl in specs] == list(snap.pages)
    assert sum(u for _d, u, _nb, _pl in specs) == snap.units == bpp

    # B never saw these pages: full materialization + copy wall
    _run_one(b, "r0")
    ev_b = next(e for e in b.events if e.kind == "restore")
    assert ev_b.detail["pages_total"] == len(specs)
    assert ev_b.detail["pages_shared"] == 0
    # A captured them, so its own restore maps every page CoW
    _run_one(a, "r1")
    ev_a = next(e for e in a.events if e.kind == "restore")
    assert ev_a.detail["pages_shared"] == ev_a.detail["pages_total"]
    # every page already mapped and no cross-host copy owed: the CoW
    # restore is a pure remap — zero wall, strictly below B's copy
    assert 0.0 <= ev_a.wall_s < ev_b.wall_s
    # both restores decoded to completion off the reassembled KV
    assert a.restore_starts == 1 and b.restore_starts == 1
    broker.check_invariants()


@pytest.mark.slow
def test_engine_without_page_size_captures_legacy_entries(setup):
    """``snapshot_page_bytes=None`` (the default) never touches the page
    store: entries carry a plain payload, restore detail has no page
    counters, and the pool charge equals the manifest-free units."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    eng = ServeEngine(cfg, params, spec, keep_alive=2.0, seed=0,
                      broker=broker, replica_id="A")
    _run_one(eng, "c0")
    _expire(eng)
    snap = broker.snapshots.peek("cnn")
    assert snap is not None and snap.pages is None
    assert len(broker.snapshots.pages) == 0
    assert broker.snapshot_units() == bpp
    _run_one(eng, "r0")
    ev = next(e for e in eng.events if e.kind == "restore")
    assert "pages_total" not in ev.detail
    broker.check_invariants()
