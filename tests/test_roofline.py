"""Roofline machinery: while-aware collective parsing, term math."""
from repro.roofline import (Roofline, parse_collective_bytes,
                            PEAK_FLOPS, HBM_BW, LINK_BW)

HLO = """
HloModule test

%body.1 (p: (s32[], bf16[4,8])) -> (s32[], bf16[4,8]) {
  %ar = bf16[4,8]{1,0} all-reduce(bf16[4,8]{1,0} %x), replica_groups={}
  %cp = bf16[4,8]{1,0} collective-permute(bf16[4,8]{1,0} %ar)
}

%cond.1 (p: (s32[], bf16[4,8])) -> pred[] {
  %c = s32[] constant(10)
}

ENTRY %main (a: bf16[16,16]) -> bf16[16,16] {
  %ag = bf16[16,16]{1,0} all-gather(bf16[1,16]{1,0} %a), dimensions={0}
  %w = (s32[], bf16[4,8]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_while_multiplied_collectives():
    out = parse_collective_bytes(HLO)
    by = out["bytes_by_op"]
    assert by["all-gather"] == 16 * 16 * 2            # result > operand
    assert by["all-reduce"] == 10 * 4 * 8 * 2         # x trip count
    assert by["collective-permute"] == 10 * 4 * 8 * 2
    assert out["counts"]["all-reduce"] == 10


def test_flat_module_without_entry():
    out = parse_collective_bytes(
        "%x = f32[8]{0} all-reduce(f32[8]{0} %y)")
    assert out["bytes_by_op"]["all-reduce"] == 32


def test_roofline_terms_and_bound():
    rl = Roofline(flops=PEAK_FLOPS, hbm_bytes=HBM_BW * 2,
                  coll_bytes=LINK_BW / 2, model_flops=PEAK_FLOPS / 2)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 2.0) < 1e-9
    assert abs(rl.collective_s - 0.5) < 1e-9
    assert rl.bound == "memory"
    assert abs(rl.step_s - 2.0) < 1e-9
    assert abs(rl.useful_ratio - 0.5) < 1e-9
    assert abs(rl.roofline_fraction - 0.25) < 1e-9
