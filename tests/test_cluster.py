"""Cluster layer: broker conservation, no double-grant, reclaim-from-idlest
ordering, router policies, cross-mode steal cost, and the single-replica
regression guard for the broker refactor.

Fast tests exercise the broker/router as pure metadata (seeded,
deterministic, invariants checked after every simulated event); the
``slow``-marked ones run real two-replica ``ServeEngine`` co-simulations.
"""
import random

import pytest

from repro.cluster import (AlwaysGrantBroker, ClusterSim, HostMemoryBroker,
                           Router)
from repro.core.arena import ArenaSpec
from repro.core.elastic import ElasticArena

SPEC = ArenaSpec(partition_tokens=64, n_partitions=8, block_tokens=16,
                 bytes_per_partition=1024)
BPP = SPEC.blocks_per_partition


# ----------------------------------------------------------------- broker


def test_broker_conservation_seeded():
    """Random request/release streams never create or destroy units."""
    rng = random.Random(0)
    broker = HostMemoryBroker(budget_units=64)
    for rid in ("a", "b", "c"):
        broker.register(rid, 8)
        broker.check_invariants()
    for _ in range(500):
        rid = rng.choice(("a", "b", "c"))
        if rng.random() < 0.5:
            got = broker.request_units(rid, rng.randint(1, 16))
            assert got >= 0
        else:
            have = broker.granted[rid]
            if have:
                broker.release_units(rid, rng.randint(1, have))
        broker.check_invariants()
        assert sum(broker.granted.values()) <= broker.budget_units


def test_broker_no_double_grant():
    """Two replicas racing for the pool can never hold more than the
    budget between them, and grants are clipped, not overcommitted."""
    broker = HostMemoryBroker(budget_units=10)
    broker.register("a", 0)
    broker.register("b", 0)
    assert broker.request_units("a", 7) == 7
    assert broker.request_units("b", 7) == 3          # only 3 left
    assert broker.request_units("b", 5) == 0          # pool empty, no victim
    broker.check_invariants()
    assert broker.granted == {"a": 7, "b": 3}
    assert broker.denied_units == 4 + 5


def test_broker_rejects_bad_release():
    broker = HostMemoryBroker(budget_units=8)
    broker.register("a", 2)
    with pytest.raises(AssertionError):
        broker.release_units("a", 3)                  # more than granted


def test_broker_register_over_budget():
    broker = HostMemoryBroker(budget_units=8)
    broker.register("a", 6)
    with pytest.raises(AssertionError):
        broker.register("b", 6)


def test_reclaim_from_idlest_ordering():
    """Under pressure the broker shrinks the idlest victim first, then the
    next-idlest, never touching the requester."""
    broker = HostMemoryBroker(budget_units=24)
    calls = []

    def mk(rid, give):
        def cb(k):
            calls.append(rid)
            got = min(k, give)
            return got, None
        return cb

    loads = {"busy": 9, "mid": 3, "idle": 0}
    for rid in ("busy", "mid", "idle"):
        broker.register(rid, 8, reclaim=mk(rid, 4),
                        load=lambda r=rid: loads[r], mode="hotmem")
    # requester "busy" needs 8; free pool is 0 -> steal 4 from idle, 4 mid
    got = broker.request_units("busy", 8)
    assert got == 8
    assert calls == ["idle", "mid"]                   # idlest first
    assert "busy" not in calls
    broker.check_invariants()
    assert len(broker.steal_log) == 2
    assert [r.victim for r in broker.steal_log] == ["idle", "mid"]
    assert all(r.requester == "busy" for r in broker.steal_log)


def test_always_grant_broker_is_unmetered():
    broker = AlwaysGrantBroker()
    broker.register("solo", 10 ** 9)
    assert broker.request_units("solo", 123) == 123
    broker.release_units("solo", 10 ** 12)            # never complains


# ----------------------------------------------------------------- router


class _FakeEngine:
    def __init__(self, load, warm=()):
        self._load = load
        self.warm = {name: [(0.0, "rid", 0)] for name in warm}

    def load(self):
        return self._load


class _Prof:
    def __init__(self, name):
        self.name = name


class _Req:
    def __init__(self, profile):
        self.profile = _Prof(profile)


def test_router_least_loaded_deterministic():
    engines = {"a": _FakeEngine(3), "b": _FakeEngine(1), "c": _FakeEngine(1)}
    r = Router("least_loaded")
    assert r.route(_Req("cnn"), engines) == "b"        # tie -> lowest id
    # backlog counts routed-but-unsubmitted work
    assert r.route(_Req("cnn"), engines, {"b": 5}) == "c"
    assert r.routed == {"b": 1, "c": 1}


def test_router_warm_affinity():
    engines = {"a": _FakeEngine(0), "b": _FakeEngine(5, warm=("cnn",))}
    r = Router("warm_affinity")
    assert r.route(_Req("cnn"), engines) == "b"        # warm beats load
    assert r.warm_routes == 1                          # route-TIME pick:
    # whether the invocation actually warm-starts is counted engine-side
    # (``warm_starts``) — see test_warm_hit_accounting_route_vs_start
    assert r.route(_Req("bert"), engines) == "a"       # no warm -> least


# --------------------------------------------- cross-mode steal (metadata)


class _ArenaReplica:
    """Minimal broker client wrapping an ElasticArena: enough to exercise a
    victim-side steal without a model (fast tier)."""

    def __init__(self, mode, seed=0):
        self.mode = mode
        per_block = max(SPEC.bytes_per_block // 2, 2)
        caches = None
        if mode == "vanilla":
            import jax.numpy as jnp
            caches = [jnp.zeros((SPEC.n_blocks, per_block), jnp.bfloat16)]
        self.arena = ElasticArena(None, SPEC, mode, caches=caches, seed=seed)

    def reclaim(self, k_blocks):
        k_parts = -(-k_blocks // BPP)
        units = k_parts if self.mode != "vanilla" else k_parts * BPP
        ev = self.arena.unplug(units)
        self.arena.manager.check_invariants()
        blocks = ev.reclaimed_units * (1 if self.mode == "vanilla" else BPP)
        return blocks, ev


@pytest.mark.parametrize("mode", ["hotmem", "vanilla"])
def test_cross_mode_steal_migration_bytes(mode):
    """THE host-level paper property: stealing from a hotmem victim moves
    zero bytes; from a vanilla victim it must migrate live blocks."""
    victim = _ArenaReplica(mode, seed=3)
    broker = HostMemoryBroker(budget_units=2 * SPEC.n_blocks)
    broker.register("victim", SPEC.n_blocks, reclaim=victim.reclaim,
                    load=lambda: 0, mode=mode)
    broker.register("loaded", SPEC.n_blocks, load=lambda: 9, mode=mode)
    # victim serves 8 requests, then all but one finish (quiet tail);
    # the survivor keeps a *low* partition (hotmem shrinks the free
    # suffix) but its vanilla blocks are scattered pool-wide — those are
    # what a vanilla steal must migrate
    for i in range(8):
        victim.arena.admit(f"r{i}")
        victim.arena.on_tokens(f"r{i}", 64)
    victim.arena.manager.check_invariants()
    for i in range(8):
        if i != 1:
            victim.arena.finish(f"r{i}")
        victim.arena.manager.check_invariants()
    got = broker.request_units("loaded", 4 * BPP)
    broker.check_invariants()
    assert got == 4 * BPP                              # steal succeeded
    assert len(broker.steal_log) == 1
    rec = broker.steal_log[0]
    assert rec.victim == "victim" and rec.mode == mode
    if mode == "hotmem":
        assert rec.migrated_bytes == 0                 # C1 at host level
    else:
        assert rec.migrated_bytes > 0                  # copies were real
    assert broker.report()["by_mode"][mode]["migrated_bytes"] \
        == rec.migrated_bytes


# --------------------------------------------- engine integration (slow)


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import model as M
    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    return cfg, params, spec


def _cluster_reqs():
    from repro.serving.request import PROFILES, Request
    from repro.serving.tracegen import assign_profiles, bursty_trace
    quiet = bursty_trace(6.0, 0.9, burst_x=1.0, burst_len=0.0, seed=2)
    burst = [4.0 + t for t in bursty_trace(4.0, 3.0, burst_x=3.0,
                                           burst_at=(0.0,), burst_len=2.0,
                                           seed=3)]
    reqs = [Request(rid=f"b{i}", profile=p, submit_s=t)
            for i, (t, p) in enumerate(assign_profiles(quiet, PROFILES, 2))]
    reqs += [Request(rid=f"a{i}", profile=p, submit_s=t)
             for i, (t, p) in enumerate(assign_profiles(burst, PROFILES, 3))]
    return reqs


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["hotmem", "vanilla"])
def test_cluster_steal_end_to_end(setup, mode):
    """Two replicas, shared budget below 2 full arenas: replica A's burst
    forces the broker to steal replica B's quiet-tail memory."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=10 * bpp)
    engines = {rid: ServeEngine(cfg, params, spec, mode=mode,
                                keep_alive=3.0, seed=i, broker=broker,
                                replica_id=rid)
               for i, rid in enumerate(("A", "B"))}
    broker.check_invariants()
    reqs = _cluster_reqs()
    sim = ClusterSim(engines, Router(route_fn=lambda r, e:
                                     "B" if r.rid.startswith("b") else "A"),
                     broker)
    m = sim.run(reqs, max_virtual_s=2000)
    broker.check_invariants()
    for e in engines.values():
        e.arena.manager.check_invariants()
    assert m["completed"] == len(reqs)
    assert m["killed"] == 0
    rep = m["broker"]
    assert rep["steals"] > 0                           # pressure engaged B
    if mode == "hotmem":
        assert rep["by_mode"][mode]["migrated_bytes"] == 0
    else:
        assert rep["by_mode"][mode]["migrated_bytes"] > 0


@pytest.mark.slow
def test_router_spreads_shared_trace(setup):
    """Least-loaded routing over a shared trace uses both replicas."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=16 * bpp)
    engines = {rid: ServeEngine(cfg, params, spec, mode="hotmem",
                                keep_alive=2.0, seed=i, broker=broker,
                                replica_id=rid)
               for i, rid in enumerate(("A", "B"))}
    reqs = _cluster_reqs()
    sim = ClusterSim(engines, Router("least_loaded"), broker)
    m = sim.run(reqs, max_virtual_s=2000)
    assert m["completed"] == len(reqs)
    assert set(m["routed"]) == {"A", "B"}              # both replicas used
    assert min(m["routed"].values()) > 0


@pytest.mark.slow
def test_hotmem_steal_evicts_warm_suffix(setup):
    """A hotmem victim must extend the free *suffix* by recycling the warm
    containers on its high rows (a low free row alone cannot be unplugged),
    and must stop at an active row without wasting warm state below it."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=100.0,
                      seed=0, prewarm=False)
    mgr = eng.arena.manager
    mgr.plug(2)                                  # ladder start 2 -> 4 rows
    for i in range(4):
        assert eng.arena.admit(f"r{i}") == i
    eng.arena.finish("r0")                       # free = {0}: low row only
    eng.warm["cnn"] = [(0.0, "r1", 1), (0.0, "r2", 2), (0.0, "r3", 3)]
    bpp = spec.blocks_per_partition
    got, ev = eng.reclaim_for_broker(2 * bpp)
    assert got == 2 * bpp                        # suffix rows 3,2 freed
    assert ev.migrated_bytes == 0
    assert mgr.plugged == 2
    assert [row for (_, _, row) in eng.warm["cnn"]] == [1]   # r1 survives
    mgr.check_invariants()


class _FakeClock:
    """Deterministic stand-in for ``time``: each perf_counter() call
    advances a fixed step, so the engine's virtual clock (and hence its
    entire schedule) replays identically run-to-run."""

    def __init__(self, step=1e-4):
        self._t = 0.0
        self._step = step

    def perf_counter(self):
        self._t += self._step
        return self._t


@pytest.mark.slow
def test_single_replica_regression(setup, monkeypatch):
    """The broker refactor must not change standalone engine behavior:
    identical metrics with the default (unmetered) broker and with an
    uncontended HostMemoryBroker, for a fixed seed/trace (under a
    deterministic clock, since the virtual timebase is wall-measured)."""
    import repro.core.elastic as elastic_mod
    import repro.core.hotmem as hotmem_mod
    import repro.core.vanilla as vanilla_mod
    import repro.serving.engine as engine_mod
    from repro.serving.engine import ServeEngine
    from repro.serving.request import PROFILES, Request
    from repro.serving.tracegen import assign_profiles, bursty_trace
    cfg, params, spec = setup

    def run(broker):
        clock = _FakeClock()
        for mod in (engine_mod, elastic_mod, hotmem_mod, vanilla_mod):
            monkeypatch.setattr(mod, "time", clock)
        arr = bursty_trace(8.0, 0.8, burst_x=5.0, burst_at=(0.0,),
                           burst_len=2.0, quiet_after=4.0, seed=11)
        reqs = [Request(rid=f"s{i}", profile=p, submit_s=t)
                for i, (t, p) in enumerate(
                    assign_profiles(arr, PROFILES, 11))]
        eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                          seed=0, broker=broker)
        return eng.run(reqs, max_virtual_s=2000)

    base = run(None)                                   # AlwaysGrantBroker
    solo = HostMemoryBroker(
        budget_units=spec.n_partitions * spec.blocks_per_partition)
    m = run(solo)
    for key in ("completed", "killed", "reclaim_events", "reclaimed_bytes",
                "migrated_bytes", "decode_steps", "latency_p50",
                "latency_p99"):
        assert m[key] == base[key], key
    assert not solo.steal_log                          # nothing to steal
    solo.check_invariants()


@pytest.mark.slow
def test_single_replica_stepevent_trace_bit_identical(setup, monkeypatch):
    """The async-broker refactor must leave standalone engines untouched:
    the full ``StepEvent`` trace — every (t, kind, wall, detail) tuple —
    is bit-identical on ``AlwaysGrantBroker``, an uncontended sync
    ``HostMemoryBroker``, and an uncontended async one (same guarantee
    PR 1 established, extended to the async protocol)."""
    import repro.core.elastic as elastic_mod
    import repro.core.hotmem as hotmem_mod
    import repro.core.vanilla as vanilla_mod
    import repro.serving.engine as engine_mod
    from repro.serving.engine import ServeEngine
    from repro.serving.request import PROFILES, Request
    from repro.serving.tracegen import assign_profiles, bursty_trace
    cfg, params, spec = setup

    def run(broker):
        clock = _FakeClock()
        for mod in (engine_mod, elastic_mod, hotmem_mod, vanilla_mod):
            monkeypatch.setattr(mod, "time", clock)
        arr = bursty_trace(8.0, 0.8, burst_x=5.0, burst_at=(0.0,),
                           burst_len=2.0, quiet_after=4.0, seed=11)
        reqs = [Request(rid=f"s{i}", profile=p, submit_s=t)
                for i, (t, p) in enumerate(
                    assign_profiles(arr, PROFILES, 11))]
        eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                          seed=0, broker=broker)
        eng.run(reqs, max_virtual_s=2000)
        return [(e.t, e.kind, e.wall_s, e.detail) for e in eng.events]

    budget = spec.n_partitions * spec.blocks_per_partition
    base = run(None)                                   # AlwaysGrantBroker
    sync_trace = run(HostMemoryBroker(budget_units=budget))
    async_trace = run(HostMemoryBroker(budget_units=budget,
                                       async_reclaim=True))
    assert sync_trace == base
    assert async_trace == base
    assert not any(kind == "stall" for _, kind, _, _ in base)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["hotmem", "vanilla"])
def test_async_cluster_end_to_end(setup, mode):
    """Two real replicas on an async broker: the trace completes, the
    requester never blocks on a victim reclaim (all request stalls are 0),
    the victim drains orders between its ticks, and the requester decodes
    while orders are still open (engine-level overlap)."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=10 * bpp, async_reclaim=True)
    engines = {rid: ServeEngine(cfg, params, spec, mode=mode,
                                keep_alive=3.0, seed=i, broker=broker,
                                replica_id=rid)
               for i, rid in enumerate(("A", "B"))}

    # spy: count A's decode steps at order issuance vs at each fill — a
    # fill at a strictly larger count proves A decoded mid-drain
    def a_decodes():
        return sum(1 for e in engines["A"].events if e.kind == "decode")

    issue_counts, fill_counts = [], []
    orig_issue = broker._issue_orders
    orig_fill = broker._apply_fill

    def spy_issue(requester, deficit, grant):
        issue_counts.append(a_decodes())
        return orig_issue(requester, deficit, grant)

    def spy_fill(o, k, **kw):
        fill_counts.append(a_decodes())
        return orig_fill(o, k, **kw)

    broker._issue_orders = spy_issue
    broker._apply_fill = spy_fill
    reqs = _cluster_reqs()
    sim = ClusterSim(engines, Router(route_fn=lambda r, e:
                                     "B" if r.rid.startswith("b") else "A"),
                     broker)
    m = sim.run(reqs, max_virtual_s=2000)
    broker.check_invariants()
    for e in engines.values():
        e.arena.manager.check_invariants()
    assert m["completed"] == len(reqs)
    assert m["killed"] == 0
    rep = m["broker"]
    assert rep["steals"] > 0                           # pressure engaged B
    assert rep["pending_units"] == 0                   # pipeline drained
    assert all(s == 0.0 for s in broker.request_stalls)
    assert issue_counts and fill_counts
    assert max(fill_counts) > min(issue_counts), \
        "no decode progressed between order issuance and a fill"
    if mode == "hotmem":
        assert rep["by_mode"][mode]["migrated_bytes"] == 0
    else:
        assert rep["by_mode"][mode]["migrated_bytes"] > 0
