"""Per-architecture smoke tests (assigned deliverable f): every arch in the
pool instantiates a REDUCED same-family config and runs one forward/train
step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_archs, reduced
from repro.models import model as M
from repro.models.layers import padded_vocab
from repro.training.optimizer import init_opt_state
from repro.training.train_step import make_batch_labels, make_train_step

B, S = 2, 16


def _batch(cfg, rng, seq=S):
    toks = jax.random.randint(rng, (B, seq), 0, cfg.vocab_size)
    batch = make_batch_labels(toks)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_src_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            rng, (B, cfg.vision_stub_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_no_nans(arch, rng):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    logits = M.forward_train(cfg, params, batch, remat=False)
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch, rng):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, rng)
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg))
    state, metrics = step(state, _batch(cfg, rng))
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", list_archs())
def test_full_configs_match_assignment(arch):
    cfg = get_config(arch)
    assert cfg.num_layers >= 12
    assert cfg.vocab_size >= 32000
    # exact assigned dims for a few spot-checked archs
    spec = {
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
    }.get(arch)
    if spec:
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == spec
