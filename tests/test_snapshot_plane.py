"""The snapshot data plane's host-side half (PR-10 satellites): the
module-level pagination / reassembly / carving helpers in
``repro.serving.engine``, driven at ADVERSARIAL page sizes — page larger
than the blob, blob not a multiple of the page, zero-unit tail pages,
single-byte pages — asserting bit-identity of the round trip and
stability of the content digests (BENCH_9's dedup baselines are keyed on
them).

The ``slow``-marked tests boot a real ``ServeEngine`` and read the
``kv_snapshot.STATS`` transfer counters: a fully-mapped local CoW
restore must move ZERO payload bytes host->device (the on-device remap
path), and the paged capture/restore still pays exactly one transfer
per direction.
"""
import hashlib
from collections import deque

import jax
import numpy as np
import pytest

from repro.cluster import HostMemoryBroker
from repro.core.arena import ArenaSpec
from repro.serving.engine import (StagedRow, assemble_pages,
                                  blob_to_row_tree, paginate_blob)
from repro.serving.request import PROFILES, Request


def _blob(n, seed=0):
    return np.frombuffer(np.random.default_rng(seed).bytes(n),
                         np.uint8).copy()


def _units(specs):
    return [u for _d, u, _b, _p in specs]


def _roundtrip(blob, units, page_bytes, n_dev=1):
    specs = paginate_blob(blob, units, page_bytes, n_dev)
    out = assemble_pages(specs)
    assert out.tobytes() == blob.tobytes(), "paginate/assemble drift"
    assert sum(_units(specs)) == units
    return specs


# ---------------------------------------- adversarial page geometries


def test_page_larger_than_blob_is_one_page():
    blob = _blob(100)
    specs = _roundtrip(blob, 8, page_bytes=4096)
    assert len(specs) == 1
    digest, units, nbytes, payload = specs[0]
    assert (units, nbytes) == (8, 100) and payload == blob.tobytes()
    assert digest == "%s-8" % hashlib.sha256(blob.tobytes()).hexdigest()[:16]


def test_blob_not_multiple_of_page_keeps_short_tail():
    blob = _blob(1000)
    specs = _roundtrip(blob, 6, page_bytes=384)   # 384+384+232
    assert [b for _d, _u, b, _p in specs] == [384, 384, 232]
    # units spread front-loaded in whole stripes: 6 over 3 pages
    assert _units(specs) == [2, 2, 2]


def test_zero_unit_tail_pages():
    """More pages than units: the tail pages charge ZERO units but still
    carry their bytes — any subset of pages reassembles, and the total
    unit charge is conserved."""
    blob = _blob(64)
    specs = _roundtrip(blob, 3, page_bytes=8)     # 8 pages, 3 units
    assert len(specs) == 8
    assert _units(specs) == [1, 1, 1, 0, 0, 0, 0, 0]
    # zero-unit pages are still content-addressed with the charge folded
    # into the digest suffix
    assert all(d.endswith("-%d" % u) for d, u, _b, _p in specs)


def test_single_byte_pages():
    blob = _blob(17, seed=3)
    specs = _roundtrip(blob, 17, page_bytes=1)
    assert len(specs) == 17
    assert all(b == 1 for _d, _u, b, _p in specs)
    # identical bytes at different offsets collide to the SAME digest —
    # that is the content-addressing contract, not a bug
    by_content = {}
    for d, _u, _b, p in specs:
        by_content.setdefault(p, set()).add(d)
    for digests in by_content.values():
        assert len(digests) == 1


def test_empty_blob_is_one_empty_page():
    specs = _roundtrip(np.zeros(0, np.uint8), 4, page_bytes=64)
    assert len(specs) == 1 and specs[0][2] == 0 and specs[0][1] == 4
    assert assemble_pages(specs).nbytes == 0


def test_mesh_stripe_unit_spread():
    """Units spread in whole n_dev stripes so any page subset charges
    balanced across devices."""
    blob = _blob(96)
    specs = _roundtrip(blob, 10, page_bytes=32, n_dev=2)  # 3 pages
    assert _units(specs) == [4, 4, 2]
    assert all(u % 2 == 0 for u in _units(specs))
    with pytest.raises(AssertionError):
        paginate_blob(blob, 7, 32, n_dev=2)       # units not striped


def test_digest_formula_is_pinned():
    """The exact digest string is a compatibility surface (dedup
    baselines and the cross-replica page store key on it): 16 hex chars
    of sha256 + '-' + unit charge.  Hard-coded literals so ANY formula
    change fails here before it silently orphans committed baselines."""
    blob = np.frombuffer(bytes(range(13)) * 3, np.uint8)   # 39 bytes
    specs = paginate_blob(blob, 3, page_bytes=16)
    assert [d for d, _u, _b, _p in specs] == [
        "0c09fd5c74ccfe4d-1", "5ae378917d45cf3d-1", "c225cb836de0531e-1"]
    empty = paginate_blob(np.zeros(0, np.uint8), 2, page_bytes=16)
    assert empty[0][0] == "e3b0c44298fc1c14-2"


def test_digests_stable_across_page_reorderings_of_same_content():
    """Same bytes, same page size, same units => same digests, no matter
    how the blob was produced (fresh array vs view of a larger staging
    buffer)."""
    base = _blob(512, seed=9)
    view = np.concatenate([_blob(64, seed=1), base,
                           _blob(64, seed=2)])[64:-64]
    a = paginate_blob(base, 8, page_bytes=128)
    b = paginate_blob(view, 8, page_bytes=128)
    assert [s[0] for s in a] == [s[0] for s in b]


# ---------------------------------------------- zero-copy carving


def test_blob_to_row_tree_views_alias_the_blob():
    """Carving a staged row never copies: every leaf is a view over the
    blob's memory, and the leaves' byte images tile the blob exactly."""
    metas = (((1, 4, 8), "float32"), ((1, 16), "float32"))
    blob = _blob((4 * 8 + 16) * 4, seed=4)
    tree = blob_to_row_tree(blob, jax.tree.structure([0, 0]), metas)
    leaves = jax.tree.leaves(tree)
    assert [tuple(x.shape) for x in leaves] == [(1, 4, 8), (1, 16)]
    for leaf in leaves:
        assert np.shares_memory(leaf, blob)
    assert b"".join(x.tobytes() for x in leaves) == blob.tobytes()


def test_staged_row_nbytes_single_source():
    """StagedRow.nbytes is the blob's byte count — the one number both
    the pool charge and pagination read (satellite: no second
    materialization)."""
    metas = (((1, 8), "float32"),)
    blob = _blob(32, seed=5)
    sr = StagedRow(blob=blob, treedef=jax.tree.structure([0]), metas=metas)
    assert sr.nbytes == 32
    assert sum(x.nbytes for x in jax.tree.leaves(sr.tree())) == sr.nbytes


# ------------------------------------- engine transfer counts (slow)


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import model as M
    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    return cfg, params, spec


def _run_one(eng, rid, prof="cnn"):
    eng.submit(Request(rid=rid, profile=PROFILES[prof], submit_s=eng.now))
    empty = deque()
    while eng.active or eng.pending:
        eng._tick(empty)


def _expire(eng):
    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()


@pytest.mark.slow
def test_fully_mapped_local_cow_restore_moves_zero_h2d_bytes(setup):
    """Acceptance criterion: when every page of a local entry is still
    resident on device, restore is an on-device remap — the payload
    never crosses the host/device boundary (zero h2d transfers, zero h2d
    bytes) and the remap counter ticks."""
    from repro.kernels import kv_snapshot
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    eng = ServeEngine(cfg, params, spec, keep_alive=2.0, seed=0,
                      broker=broker, replica_id="A",
                      snapshot_page_bytes=4096)
    _run_one(eng, "c0")
    _expire(eng)                                  # capture + page index
    snap = broker.snapshots.peek("cnn")
    assert snap is not None and snap.pages is not None

    kv_snapshot.reset_stats()
    _run_one(eng, "r0")                           # every page device-mapped
    assert eng.restore_starts == 1
    s = kv_snapshot.STATS
    assert s["remap_restores"] == 1
    assert s["h2d_transfers"] == 0 and s["h2d_bytes"] == 0
    assert s["restore_launches"] == 1             # still ONE fused scatter
    ev = next(e for e in eng.events if e.kind == "restore")
    assert ev.detail["pages_shared"] == ev.detail["pages_total"]
    broker.check_invariants()


@pytest.mark.slow
def test_paged_restore_on_fresh_replica_pays_one_h2d(setup):
    """A replica with none of the pages materializes them with ONE fused
    host->device copy of the whole blob (not one per page, not one per
    leaf)."""
    from repro.kernels import kv_snapshot
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    a = ServeEngine(cfg, params, spec, keep_alive=2.0, seed=0,
                    broker=broker, replica_id="A",
                    snapshot_page_bytes=4096)
    b = ServeEngine(cfg, params, spec, keep_alive=2.0, seed=1,
                    broker=broker, replica_id="B",
                    snapshot_page_bytes=4096)
    _run_one(a, "c0")
    _expire(a)
    layout = a._snapshot_layout()

    kv_snapshot.reset_stats()
    _run_one(b, "r0")
    assert b.restore_starts == 1
    s = kv_snapshot.STATS
    assert s["h2d_transfers"] == 1
    assert s["h2d_bytes"] == layout.row_bytes
    assert s["remap_restores"] == 0
    assert s["restore_launches"] == 1
    broker.check_invariants()
