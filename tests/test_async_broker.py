"""Deterministic-concurrency tests for the async host reclaim pipeline.

The broker's asynchrony is cooperative (orders drain at tick boundaries),
so every interleaving here is *scripted* — grant issuance, partial
fulfillment, natural release, claim, and cancel are stepped explicitly (or
driven through ``ClusterSim`` with deterministic stub replicas) and the
conservation invariant ``free + granted + escrow == budget`` is checked
after every event.  The three properties the tier pins down:

  (a) conservation holds at every step of a scripted
      grant/steal/release/cancel schedule;
  (b) a requester's decode proceeds while a victim's reclaim order is
      still draining (overlap, proven on the fake virtual clock);
  (c) a victim finishing naturally fills/cancels the remainder of its
      open order without double-releasing units.
"""
import pytest

from repro.cluster import ClusterSim, HostMemoryBroker, Router
from repro.serving.request import PROFILES, Request


from conftest import StubReplica as _StubReplica, \
    fake_clock as _fake_clock, mk_async_broker as _mk


# ----------------------------------------------------- (a) conservation


def test_conservation_scripted_schedule():
    """free + granted + escrow == budget after EVERY event of a scripted
    grant/partial-fill/release/claim/cancel interleaving."""
    loads = {"a": 5, "b": 0, "c": 1}
    broker, sinks = _mk(24, [("a", 8), ("b", 8), ("c", 8)], loads=loads)
    broker.check_invariants()

    g = broker.request_grant("a", 10)          # free pool is empty
    broker.check_invariants()
    assert g.granted == 0 and g.pending == 10 and not g.done
    # orders go to the idlest victims first, capped by their holdings
    ob, oc = sinks["b"][0], sinks["c"][0]
    assert (ob.victim, ob.units) == ("b", 8)
    assert (oc.victim, oc.units) == ("c", 2)
    assert broker.pending_units() == 10
    assert broker.pressure() == 10 / 24

    # victim b drains a partial chunk
    assert broker.fulfill_order(ob.order_id, 3) == 3
    broker.check_invariants()
    assert g.available == 3 and g.pending == 7
    assert broker.granted["b"] == 5 and broker.escrow_units() == 3

    # requester claims mid-drain (grant completion is incremental too)
    assert broker.claim_grant(g) == 3
    broker.check_invariants()
    assert broker.granted["a"] == 11 and g.claimed == 3

    # a release from a replica WITHOUT open orders goes to the pool
    broker.release_units("a", 1)
    broker.check_invariants()
    assert broker.free_units == 1

    # the victim releasing naturally routes INTO its open order (c)
    broker.release_units("b", 2)
    broker.check_invariants()
    assert ob.filled == 5 and broker.free_units == 1
    assert g.available == 2

    # over-fulfillment is clipped to the remainder
    assert broker.fulfill_order(ob.order_id, 99) == 3
    broker.check_invariants()
    assert not ob.open and broker.granted["b"] == 0

    # victim c cannot supply: cancels its remainder
    assert broker.cancel_order(oc.order_id) == 2
    broker.check_invariants()
    assert g.done and g.pending == 0

    assert broker.claim_grant(g) == 5
    broker.check_invariants()
    assert g not in broker.grants
    assert g.fulfilled <= g.requested
    assert broker.granted == {"a": 15, "b": 0, "c": 8}
    assert broker.free_units == 1


def test_conservation_with_snapshot_interleaving():
    """The extended conservation law ``free + granted + escrow +
    snapshot_units == budget`` holds after EVERY event of a schedule
    interleaving snapshot inserts/restores/drops with grants (and their
    snapshot-first squeezes), partial order fills, claims, and cancels."""
    broker, sinks = _mk(24, [("a", 8), ("b", 8)], pool_units=12)
    broker.check_invariants()

    assert broker.snapshot_put("cnn", units=3)     # free 8 -> 5
    broker.check_invariants()
    assert broker.snapshot_put("bert", units=4)    # free 5 -> 1
    broker.check_invariants()
    assert broker.snapshot_units() == 7

    # a's plug: free pool (1) + squeeze BOTH snapshots (7) cover it fully
    g = broker.request_grant("a", 6)
    broker.check_invariants()
    assert g.done and g.granted == 6
    assert not sinks["a"] and not sinks["b"]       # pool covered: no order
    assert broker.snapshot_units() == 0 and broker.free_units == 2
    assert len(broker.squeeze_log) == 2

    assert broker.snapshot_put("cnn", units=2)     # free 2 -> 0
    broker.check_invariants()

    # b's plug: squeeze the fresh snapshot, order only the remainder
    g2 = broker.request_grant("b", 5)
    broker.check_invariants()
    assert g2.granted == 2 and g2.pending == 3
    oa = sinks["a"][0]
    assert (oa.victim, oa.units) == ("a", 3)

    assert broker.fulfill_order(oa.order_id, 2) == 2   # escrow 2
    broker.check_invariants()
    # with escrow in flight and the pool empty, an insert cannot fit
    assert not broker.snapshot_put("html", units=1)
    broker.check_invariants()

    assert broker.claim_grant(g2) == 2
    broker.check_invariants()
    assert broker.cancel_order(oa.order_id) == 1
    broker.check_invariants()
    assert g2.done

    broker.release_units("a", 4)                   # order closed: -> pool
    broker.check_invariants()
    assert broker.snapshot_put("html", units=4)    # free 4 -> 0
    broker.check_invariants()
    snap = broker.snapshot_lookup("html")          # restore-path fetch
    assert snap is not None and snap.restores == 1
    broker.check_invariants()
    assert broker.snapshot_drop("html") == 4       # charge returns
    broker.check_invariants()
    assert broker.granted == {"a": 8, "b": 12}
    assert broker.free_units == 4 and broker.snapshot_units() == 0


def test_request_grant_fills_from_pool_first():
    broker, sinks = _mk(16, [("a", 4), ("b", 6)])
    g = broker.request_grant("a", 9)           # free = 6
    broker.check_invariants()
    assert g.granted == 6 and g.pending == 3
    assert sinks["b"][0].units == 3
    # legacy blocking call returns only the immediate portion AND cancels
    # the orders it issued — a legacy caller can never claim their fills,
    # which would strand the proceeds in escrow forever
    assert broker.request_units("a", 2) == 0
    broker.check_invariants()
    assert broker.pending_units() == 3         # only g's order survives
    # b draining everything it owes leaves nothing stranded
    broker.release_units("b", 6)
    broker.check_invariants()
    assert broker.claim_grant(g) == 3
    assert broker.free_units == 3 and broker.escrow_units() == 0


def test_abandoned_grant_stops_the_drain():
    """A requester whose demand vanished abandons its grant: the victim's
    order closes, escrowed units remain claimable, nothing leaks."""
    broker, sinks = _mk(8, [("a", 2), ("b", 6)])
    g = broker.request_grant("a", 6)
    broker.fulfill_order(sinks["b"][0].order_id, 2)
    broker.check_invariants()
    assert broker.abandon_grant(g) == 4
    broker.check_invariants()
    assert not sinks["b"][0].open and g.pending == 0
    assert broker.claim_grant(g) == 2          # escrow still delivered
    broker.check_invariants()
    assert g not in broker.grants
    assert broker.granted == {"a": 4, "b": 4}


def test_orders_capped_by_outstanding():
    """A victim is never ordered to return more than it holds, counting
    units already promised to earlier orders."""
    broker, sinks = _mk(12, [("a", 2), ("b", 10)])
    g1 = broker.request_grant("a", 6)
    g2 = broker.request_grant("a", 8)
    broker.check_invariants()
    assert g1.pending == 6
    # b holds 10, 6 already ordered -> only 4 more can be promised
    assert g2.pending == 4
    assert broker.denied_units == 4
    assert broker.open_order_units("b") == 10


# ------------------------------------------- (b) overlap on the fake clock


# the deterministic stub replica lives in tests/conftest.py
# (``StubReplica``) — the fleet suite scripts multi-host schedules with
# the same stub, so there is exactly one definition of its timings


def test_decode_overlaps_order_drain_on_fake_clock():
    """THE async property: the requester keeps decoding while the victim's
    reclaim order is still draining — scripted through the real
    ``ClusterSim`` interleaver on the deterministic virtual clock."""
    broker = HostMemoryBroker(16, async_reclaim=True, clock=_fake_clock())
    a = _StubReplica("a", broker, units=4, decode_steps=10)
    b = _StubReplica("b", broker, units=12)
    g = a.request(8)                           # free pool empty -> all async
    assert g.granted == 0 and g.pending == 8   # requester NOT blocked
    broker.check_invariants()

    req = Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0)
    sim = ClusterSim({"a": a, "b": b},
                     Router(route_fn=lambda r, e: "a"), broker)
    m = sim.run([req], max_virtual_s=100)
    broker.check_invariants()

    assert m["completed"] == 1
    assert g.done and g.claimed == 8           # grant completed via fills
    assert a.units == 4 + 8 and b.units == 4
    decodes = [e for e in a.events if e[1] == "decode"]
    drains = [e for e in b.events if e[1] == "drain"]
    assert len(decodes) == 10 and len(drains) == 8
    # overlap proven: at least one decode ran while units were still owed
    overlapped = [e for e in decodes if e[2] > 0]
    assert overlapped, "no decode step overlapped the open reclaim order"
    # and the drain really was incremental: fills arrived across ticks
    fills = [e for e in a.events if e[1] == "fill"]
    assert len(fills) >= 2
    # deterministic replay: the schedule is a pure function of the script
    assert decodes[0][0] == pytest.approx(1.0)
    assert drains[0][0] == pytest.approx(0.25)


def test_sync_broker_has_no_overlap_async_does():
    """Contrast fixture for the benchmark's stall column: the sync broker
    reports a positive requester-visible stall; the async broker's is 0."""
    calls = []

    def reclaim(k):
        calls.append(k)
        return min(k, 4), None                 # b only holds 4

    sync = HostMemoryBroker(8, clock=_fake_clock())
    sync.register("a", 4)
    sync.register("b", 4, reclaim=reclaim, load=lambda: 0)
    g = sync.request_grant("a", 8)
    assert calls and g.stall_seconds > 0       # serialized behind victim
    assert sync.request_stalls and max(sync.request_stalls) > 0

    broker, _ = _mk(8, [("a", 4), ("b", 4)])
    g = broker.request_grant("a", 8)
    assert g.stall_seconds == 0.0
    assert broker.request_stalls == [0.0]


# ------------------------------------- (c) natural finish / cancel safety


def test_natural_finish_fills_order_without_double_release():
    """A victim finishing naturally releases its units once: they route
    into the open order (feeding the requester), never ALSO to the pool."""
    broker, sinks = _mk(8, [("a", 2), ("b", 6)])
    g = broker.request_grant("a", 6)
    o = sinks["b"][0]
    assert o.units == 6
    # b's workload ends: it releases 4 units the normal way
    broker.release_units("b", 4)
    broker.check_invariants()
    assert o.filled == 4 and g.available == 4
    assert broker.free_units == 0              # NOT double-credited
    assert broker.granted["b"] == 2
    # b has nothing left to give: cancel the remainder
    assert broker.cancel_order(o.order_id) == 2
    broker.check_invariants()
    assert not o.open and g.pending == 0
    assert broker.claim_grant(g) == 4
    broker.check_invariants()
    assert broker.granted == {"a": 6, "b": 2}
    # the released units are gone from b — releasing again must fail
    with pytest.raises(AssertionError):
        broker.release_units("b", 3)


def test_cancel_closes_grant_and_counts_denied():
    broker, sinks = _mk(6, [("a", 2), ("b", 4)])
    g = broker.request_grant("a", 4)
    o = sinks["b"][0]
    assert broker.cancel_order(o.order_id) == 4
    broker.check_invariants()
    assert g.done and g not in broker.grants
    assert broker.denied_units == 4
    assert not o.open and o.closed_at is not None


def test_release_beyond_orders_reaches_pool():
    broker, sinks = _mk(8, [("a", 2), ("b", 6)])
    broker.request_grant("a", 2)               # order b for 2
    broker.release_units("b", 5)               # 2 fill the order, 3 -> pool
    broker.check_invariants()
    assert broker.free_units == 3
    assert broker.granted["b"] == 1
    assert not sinks["b"][0].open


# -------------------------------------------------- pressure-aware routing


class _FakeEngine:
    def __init__(self, load):
        self._load = load
        self.warm = {}

    def load(self):
        return self._load


def test_power_of_two_avoids_draining_victim():
    """p2c prefers the sampled replica WITHOUT open reclaim orders, even
    when the draining one is less loaded."""
    broker, sinks = _mk(8, [("a", 2), ("b", 6)], loads={"a": 9, "b": 0})
    broker.request_grant("a", 3)               # b now owes 3 (draining)
    assert broker.open_order_units("b") == 3
    engines = {"a": _FakeEngine(9), "b": _FakeEngine(0)}
    r = Router("power_of_two", broker=broker)
    req = Request(rid="x", profile=PROFILES["cnn"], submit_s=0.0)
    assert r.route(req, engines) == "a"        # dodges the victim
    assert r.drain_avoided == 1
    # once the order is drained, load wins again
    broker.fulfill_order(sinks["b"][0].order_id, 3)
    broker.check_invariants()
    assert r.route(req, engines) == "b"


def test_power_of_two_deterministic_sampling():
    engines = {f"r{i}": _FakeEngine(i) for i in range(4)}
    req = Request(rid="x", profile=PROFILES["cnn"], submit_s=0.0)
    picks1 = [Router("power_of_two", seed=7).route(req, dict(engines))
              for _ in range(10)]
    r2 = Router("power_of_two", seed=7)
    picks2 = [r2.route(req, dict(engines)) for _ in range(10)]
    # same seed, same trace -> byte-identical routing... but each Router
    # advances its own rng, so compare a fresh router per call vs a
    # replayed sequence from an identically-seeded router
    r3 = Router("power_of_two", seed=7)
    picks3 = [r3.route(req, dict(engines)) for _ in range(10)]
    assert picks2 == picks3
    assert all(p == picks1[0] for p in picks1)
