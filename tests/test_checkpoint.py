"""Fault tolerance: atomic manifest commits, resume-after-crash continuity,
elastic reshard-on-load."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.training import checkpoint as ckpt


def test_atomic_commit_ignores_partial(tmp_path):
    d = str(tmp_path)
    state = {"a": np.arange(4.0), "b": {"c": np.ones((2, 2))}}
    ckpt.save(d, 10, state)
    # a crashed save: directory without manifest
    os.makedirs(os.path.join(d, "step_20"))
    assert ckpt.latest(d) == 10
    got = ckpt.restore(d, 10, state)
    np.testing.assert_array_equal(got["a"], state["a"])
    np.testing.assert_array_equal(got["b"]["c"], state["b"]["c"])


def test_crash_resume_continuity(tmp_path):
    """Train 12 steps with a crash at step 8; resume must complete and the
    final state must equal an uninterrupted run (pure-function data
    pipeline + step-indexed checkpoints)."""
    d = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="injected failure"):
        train("qwen2-1.5b", steps=12, batch=2, seq=16, ckpt_dir=d,
              ckpt_every=4, fail_at_step=8, log_every=100)
    # the async step-8 save may or may not have committed before the crash
    # (both are legal); either way resume must reach the clean-run state
    assert ckpt.latest(d) in (4, 8)
    state_resumed, _ = train("qwen2-1.5b", steps=12, batch=2, seq=16,
                             ckpt_dir=d, ckpt_every=4, resume=True,
                             log_every=100)
    state_clean, _ = train("qwen2-1.5b", steps=12, batch=2, seq=16,
                           ckpt_dir=str(tmp_path / "clean"), ckpt_every=100,
                           log_every=100)
    for a, b in zip(jax.tree.leaves(state_resumed["params"]),
                    jax.tree.leaves(state_clean["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-2, rtol=1e-2)


def test_elastic_reshard_on_load(tmp_path):
    """Restore with explicit target shardings (mesh-B placement for a
    mesh-A checkpoint)."""
    d = str(tmp_path)
    state = {"w": np.arange(16.0).reshape(4, 4)}
    ckpt.save(d, 1, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    got = ckpt.restore(d, 1, state, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), state["w"])


def test_async_save_overlaps(tmp_path):
    d = str(tmp_path)
    t = ckpt.save(d, 5, {"x": np.ones(8)}, blocking=False)
    t.join()
    assert ckpt.latest(d) == 5
