"""Launch-layer tests: input specs for every assigned cell, report merge
semantics, grad-accum derivation."""
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.report import load
from repro.launch.specs import default_grad_accum, input_specs


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_construct_for_every_cell(arch, shape):
    """Every (arch x shape) cell's inputs must be constructible as abstract
    specs (shape/dtype sanity without any device allocation)."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.supports_long_context:
        pytest.skip("assigned long_500k skip")
    specs = input_specs(cfg, shape, mesh=None)
    cell = SHAPES[shape]
    if cell.kind == "train":
        assert specs["batch"]["tokens"].shape == (cell.global_batch,
                                                  cell.seq_len)
        assert specs["batch"]["labels"].dtype == jnp.int32
        assert "master" in specs["state"]["opt"]
    elif cell.kind == "prefill":
        assert specs["batch"]["tokens"].shape == (cell.global_batch,
                                                  cell.seq_len)
        assert specs["caches"]
    else:
        assert specs["tokens"].shape == (cell.global_batch, 1)
        assert specs["positions"].shape == (cell.global_batch,)


def test_report_later_files_win(tmp_path):
    a = tmp_path / "a.jsonl"
    b = tmp_path / "b.jsonl"
    a.write_text('{"arch":"x","shape":"s","mesh":"16x16","ok":false}\n')
    b.write_text('{"arch":"x","shape":"s","mesh":"16x16","ok":true}\n')
    cells = load([str(a), str(b)])
    assert cells[("x", "s", "16x16")]["ok"] is True


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_grad_accum_respects_batch_rule():
    cfg = get_config("tinyllama-1.1b")
    cell = SHAPES["train_4k"]
    mesh = _FakeMesh({"data": 16, "model": 16})
    base = default_grad_accum(cfg, cell, mesh, {"batch": ("pod", "data")})
    dp = default_grad_accum(cfg, cell, mesh,
                            {"batch": ("pod", "data", "model")})
    assert dp <= base        # 256-way batch sharding -> fewer microbatches
    assert dp == 1
