"""Sharding rule-engine unit tests: divisibility fallbacks, axis-conflict
avoidance, prefix fallback for multi-axis rules."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import (SERVE_RULES, TRAIN_RULES, ShardCtx, spec_for,
                            serve_rules_for, train_rules_for)
from repro.configs.base import get_config


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def _ctx(shape=None, rules=TRAIN_RULES):
    return ShardCtx(FakeMesh(shape or {"data": 16, "model": 16}), rules)


def test_even_division_shards():
    spec = spec_for(("batch", "seq", "embed"), (256, 4096, 1024), _ctx())
    assert spec == P("data", None, None)     # no 'pod' in mesh


def test_uneven_head_count_replicates():
    # 28 heads over 16-way model: strict divisibility -> replicated
    spec = spec_for(("batch", "seq", "heads", None), (256, 128, 28, 128),
                    _ctx())
    assert spec[2] is None


def test_multi_axis_prefix_fallback():
    ctx = _ctx({"pod": 2, "data": 16, "model": 16})
    # batch 32 divides pod*data=32 fully
    assert spec_for(("batch",), (32,), ctx) == P(("pod", "data"))
    # batch 2 only divides the 'pod' prefix
    assert spec_for(("batch",), (2,), ctx) == P("pod")
    # batch 1 divides nothing -> replicated
    assert spec_for(("batch",), (1,), ctx) == P(None)


def test_axis_used_once_per_tensor():
    ctx = _ctx(rules=dict(TRAIN_RULES, embed=("data",)))
    # batch consumes 'data'; embed must not reuse it
    spec = spec_for(("batch", "seq", "embed"), (256, 128, 1024), ctx)
    assert spec == P("data", None, None)


def test_serve_rules_shard_kv_seq_not_heads():
    spec = spec_for(("batch", "kv_seq", "kv_heads", None),
                    (128, 32768, 8, 128), _ctx(rules=SERVE_RULES))
    assert spec == P("data", "model", None, None)


def test_big_model_gets_2d_weights():
    big = serve_rules_for(get_config("qwen2-vl-72b"), "decode_32k")
    small = serve_rules_for(get_config("qwen2-7b"), "decode_32k")
    assert big["w_embed"] == ("pod", "data")
    assert small["w_embed"] is None


def test_long_context_rules_use_cp():
    rules = serve_rules_for(get_config("mamba2-780m"), "long_500k")
    assert rules["kv_seq"] == ("data", "model")
    assert rules["batch"] is None


def test_moe_tp_rules():
    rules = train_rules_for(get_config("mixtral-8x7b"))
    assert rules["experts"] is None
    assert rules["expert_mlp"] == "model"
    rules_ep = train_rules_for(get_config("dbrx-132b"))
    assert rules_ep["experts"] == "model"
