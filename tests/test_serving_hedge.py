"""Straggler mitigation: hedged dispatch + supervised restart contracts."""
import pytest

from repro.launch.distributed import hedged_dispatch, run_with_restarts


class FakeReplica:
    def __init__(self, load, ttft):
        self._load = load
        self.ttft = ttft
        self.submissions = 0

    def load(self):
        return self._load


def test_hedge_picks_least_loaded_fast_replica():
    reps = [FakeReplica(0.9, 0.01), FakeReplica(0.1, 0.01)]

    def submit(i):
        reps[i].submissions += 1
        return reps[i].ttft

    chosen = hedged_dispatch(reps, submit, deadline_s=0.1)
    assert chosen == [1]                      # least loaded, fast enough
    assert reps[1].submissions == 1
    assert reps[0].submissions == 0


def test_hedge_fires_backup_on_straggler():
    reps = [FakeReplica(0.1, 5.0), FakeReplica(0.5, 0.01)]

    def submit(i):
        reps[i].submissions += 1
        return reps[i].ttft

    chosen = hedged_dispatch(reps, submit, deadline_s=0.1)
    assert chosen == [0, 1]                   # straggler -> hedge
    assert reps[0].submissions == 1
    assert reps[1].submissions == 1


def test_run_with_restarts_recovers(monkeypatch):
    monkeypatch.setattr("time.sleep", lambda s: None)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("preempted")

    run_with_restarts(flaky, max_restarts=5, backoff_s=0.0)
    assert calls["n"] == 3


def test_run_with_restarts_bounded(monkeypatch):
    monkeypatch.setattr("time.sleep", lambda s: None)

    def always_fails():
        raise RuntimeError("bad node")

    with pytest.raises(RuntimeError, match="bad node"):
        run_with_restarts(always_fails, max_restarts=2, backoff_s=0.0)
