"""Host lifecycle on the fleet: boot/retire with drain-via-migration,
the contended interconnect, the migration budget — and the capacity /
routing / truncation bugs the autoscaler exposed.

The properties pinned down:

  (a) placement capacity honesty: ``FleetScheduler.capacity`` counts
      only the snapshot charge a boot-time squeeze could ACTUALLY drop
      under the tenant-fairness rule — summing the whole pool charge
      promised capacity ``register`` then failed to deliver when the
      pool was full of sub-budget-protected entries;
  (b) retirement: a retiring host accepts no placements, the router
      masks its replicas in every tier, its pool DRAINS to peers via
      ``migrate_snapshot`` (restorable entries move; metadata-only ones
      drop; roomless ones defer until force), and the host is removed
      only once its ledger shows ``free == budget`` — with per-host
      conservation checked after every lifecycle event;
  (c) the interconnect is honest: concurrent transfers sharing an
      endpoint split its bandwidth (two concurrent migrations pay 2x
      the byte wall of one; disjoint endpoint pairs don't contend), and
      ``migration_budget_bytes`` defers drain traffic while foreground
      ``ensure_local`` restores always proceed;
  (d) ``snapshot_affinity``'s cold fallback routes through ``_pick``
      (least-loaded among NON-draining replicas, ``drain_avoided``
      counted) instead of pure load order landing on mid-reclaim
      victims exactly when nothing was cached;
  (e) a ``FleetSim.run`` that exhausts ``max_ticks`` warns loudly and
      flags ``metrics()["truncated"]`` instead of returning partial
      metrics indistinguishable from a finished trace;
  (f) autoscaled scenario rows (boot + retire mid-run) replay
      bit-identically for a fixed seed.
"""
import json

import pytest

from repro.cluster import (FleetScheduler, FleetSim, HostMemoryBroker,
                           Router)
from repro.cluster.fleet import AutoscalePolicy
from repro.serving.request import PROFILES, Request

from conftest import StubReplica, fake_clock as _fake_clock, \
    mk_async_broker as _mk_async


def _fleet(budgets, *, pool_units=None, bandwidth=1024.0, latency=0.5,
           budget_bytes=None):
    """Fleet of sync brokers on fake clocks (1.0 per reading, separate
    instance per component); bandwidth in bytes/virtual-second so
    modeled copy walls are exact small numbers."""
    sched = FleetScheduler(bandwidth_bytes_per_s=bandwidth,
                           link_latency_s=latency,
                           migration_budget_bytes=budget_bytes,
                           clock=_fake_clock())
    for h, b in budgets.items():
        sched.add_host(h, HostMemoryBroker(
            b, clock=_fake_clock(), snapshot_pool_units=pool_units))
    return sched


class _FakeEngine:
    def __init__(self, load, warm=()):
        self._load = load
        self.warm = {name: [(0.0, "rid", 0)] for name in warm}

    def load(self):
        return self._load


def _req(profile="cnn"):
    return Request(rid="x", profile=PROFILES[profile], submit_s=0.0)


# ------------------------------------------------- (a) capacity honesty


def test_capacity_excludes_protected_snapshot_charge():
    """The placement bug: a pool full of another tenant's entries at its
    sub-budget contributes ZERO boot-squeeze capacity, so ``place`` no
    longer promises units ``register`` cannot deliver."""
    def mk(budget=8):
        return HostMemoryBroker(budget, clock=_fake_clock(),
                                snapshot_pool_units=4,
                                tenants={"a": 4, "b": 4})
    b0 = mk()
    for i in range(4):
        assert b0.snapshot_put(f"k{i}", units=1, payload=object(),
                               tenant="a")
    # tenant a's usage (4 snapshot units) == its sub-budget: every entry
    # is protected from b's pressure
    assert b0.snapshot_units() == 4
    assert b0.squeezable_snapshot_units("b") == 0
    assert b0.squeezable_snapshot_units("a") == 4     # own entries: free
    sched = FleetScheduler(clock=_fake_clock())
    sched.add_host("h0", b0)
    assert sched.capacity("h0", tenant="b") == 4      # was 8 pre-fix
    # no host can actually fit 5 units of b: place refuses instead of
    # over-promising
    with pytest.raises(AssertionError, match="no host can fit"):
        sched.place("b0", 5, tenant="b")
    # a peer with 5 genuinely free units wins spread placement even
    # though h0's NAIVE free+pool figure (8) is larger
    b1 = mk()
    b1.register("pad", 3, tenant="a")
    sched.add_host("h1", b1)
    assert sched.capacity("h1", tenant="b") == 5
    assert sched.place("b0", 5, tenant="b") == "h1"


def test_squeezable_probe_is_sequential_not_a_sum():
    """Partial protection: an owner 2 units above its sub-budget with
    four 1-unit entries can spare exactly 2 — the probe simulates
    sequential drops (re-evaluating post-drop usage), it does not sum
    per-entry eligibility."""
    b = HostMemoryBroker(8, clock=_fake_clock(), snapshot_pool_units=4,
                         tenants={"a": 2, "b": 6})
    for i in range(4):
        assert b.snapshot_put(f"k{i}", units=1, payload=object(),
                              tenant="a")
    assert b.squeezable_snapshot_units("b") == 2
    sched = FleetScheduler(clock=_fake_clock())
    sched.add_host("h0", b)
    assert sched.capacity("h0", tenant="b") == 4 + 2


def test_anonymous_capacity_probe_is_the_conservative_floor():
    """``tenant=None`` on a multi-tenant ledger treats every entry as
    another tenant's; on a single-tenant ledger it resolves to the sole
    tenant (own entries — fully droppable, the legacy figure)."""
    multi = HostMemoryBroker(8, clock=_fake_clock(),
                             snapshot_pool_units=4,
                             tenants={"a": 4, "b": 4})
    assert multi.snapshot_put("k", units=1, payload=object(), tenant="a")
    assert multi.squeezable_snapshot_units() == 0     # a is at sub-budget
    single = HostMemoryBroker(8, clock=_fake_clock(),
                              snapshot_pool_units=4)
    single.register("r", 2)
    assert single.snapshot_put("k", units=2, payload=object())
    assert single.squeezable_snapshot_units() == 2


# ------------------------------------------------------- (b) retirement


def test_retire_drain_migrates_every_restorable_entry():
    """The acceptance path: a retiring host migrates (does NOT discard)
    every restorable snapshot when peers have room; metadata-only
    entries drop; the host is removed only at ``free == budget`` and
    its id is never reused."""
    sched = _fleet({"h0": 8, "h1": 8}, pool_units=4)
    b0 = sched.brokers["h0"]
    for k in ("k0", "k1"):
        assert b0.snapshot_put(k, units=1, payload=("kv", k), nbytes=512)
    assert b0.snapshot_put("meta", units=1, payload=None)  # unrestorable
    sched.begin_retire("h0")
    assert sched.active_hosts() == ["h1"]
    assert sched.place("x", 2) == "h1"       # retiring: no placements
    stats = sched.drain_host("h0")
    assert stats == {"migrated": 2, "deferred": 0, "discarded": 1}
    assert sched.drain_discarded == 1
    for k in ("k0", "k1"):
        assert sched.brokers["h1"].snapshot_restorable(k)
    assert b0.free_units == b0.budget_units
    assert sched.finish_retire("h0")
    assert "h0" in sched.retired and "h0" not in sched.brokers
    assert sched.host_retires == 1
    sched.check_invariants()
    with pytest.raises(AssertionError, match="never reused"):
        sched.add_host("h0", HostMemoryBroker(8, clock=_fake_clock()))


def test_retire_defers_without_room_then_migrates_when_it_appears():
    """A restorable entry with no peer room is left for the next pump —
    room may yet appear (and does, once the peer's replica shrinks)."""
    sched = _fleet({"h0": 8, "h1": 8}, pool_units=2)
    sched.brokers["h0"].snapshot_put("k", units=1, payload=object())
    sched.brokers["h1"].register("r1", 8)    # peer: zero free units
    sched.begin_retire("h0")
    assert sched.drain_host("h0") \
        == {"migrated": 0, "deferred": 1, "discarded": 0}
    assert not sched.finish_retire("h0")     # pool still charged
    sched.brokers["h1"].release_units("r1", 4)
    assert sched.drain_host("h0") \
        == {"migrated": 1, "deferred": 0, "discarded": 0}
    assert sched.brokers["h1"].snapshot_restorable("k")
    assert sched.finish_retire("h0")
    assert sched.drain_discarded == 0


def test_force_drain_discards_roomless_entries():
    """End-of-run finalization: no foreground traffic remains, so a
    roomless entry is dropped rather than stranding the retirement."""
    sched = _fleet({"h0": 8, "h1": 8}, pool_units=2)
    sched.brokers["h0"].snapshot_put("k", units=1, payload=object())
    sched.brokers["h1"].register("r1", 8)
    sched.begin_retire("h0")
    assert sched.drain_host("h0", force=True) \
        == {"migrated": 0, "deferred": 0, "discarded": 1}
    assert sched.drain_discarded == 1
    assert sched.finish_retire("h0")


def test_deregister_settles_the_account_and_frees_the_id():
    broker, _ = _mk_async(8, [("a", 2)])
    assert broker.free_units == 6
    assert broker.deregister("a") == 2
    assert broker.free_units == 8 and "a" not in broker.granted
    broker.check_invariants()
    broker.register("a", 3)                  # fully forgotten: reusable
    assert broker.granted["a"] == 3


def test_router_masks_retiring_and_retired_hosts():
    sched = _fleet({"h0": 8, "h1": 8})
    sched.placements.update({"a": "h0", "b": "h1"})
    r = Router("least_loaded", fleet=sched)
    engines = {"a": _FakeEngine(5), "b": _FakeEngine(0)}
    assert r.route(_req(), engines) == "b"   # plain least-loaded
    sched.begin_retire("h1")
    assert r.route(_req(), engines) == "a"   # retiring host masked
    assert sched.finish_retire("h1")         # empty ledger: gone at once
    assert r.route(_req(), engines) == "a"   # decommissioned: still masked
    sched.begin_retire("h0")
    # the whole fleet retiring: an arrival must still route somewhere
    assert r.route(_req(), engines) == "b"


def test_autoscale_policy_validates_thresholds():
    with pytest.raises(AssertionError):
        AutoscalePolicy(low_water=5, high_water=3, quiet_ticks=10)
    with pytest.raises(AssertionError):
        AutoscalePolicy(low_water=0, high_water=1, quiet_ticks=0)
    with pytest.raises(AssertionError):
        AutoscalePolicy(low_water=0, high_water=1, quiet_ticks=1,
                        min_hosts=4, max_hosts=2)
    with pytest.raises(AssertionError):
        AutoscalePolicy(low_water=0, high_water=1, quiet_ticks=1,
                        boot_latency_s=-0.5)


def test_boot_latency_gates_routing_but_not_capacity():
    """A freshly booted host is provisioning for ``ready_delay`` virtual
    seconds: it COUNTS toward fleet capacity at once (so the autoscaler
    does not stampede more boots for the same deficit) and accepts
    placements, but the router masks its replicas until the clock
    passes its ready time."""
    t = [0.0]
    sched = FleetScheduler(clock=lambda: t[0])
    sched.add_host("h0", HostMemoryBroker(8, clock=_fake_clock()))
    sched.brokers["h0"].register("a", 4)
    sched.placements["a"] = "h0"
    sched.boot_host("h1", HostMemoryBroker(8, clock=_fake_clock()),
                    ready_delay=5.0)
    assert sched.host_boots == 1
    assert sched.host_ready("h0") and not sched.host_ready("h1")
    assert sched.report()["booting"] == ["h1"]
    # capacity and placement see the booting host immediately
    assert sched.capacity("h1") == 8
    assert sched.place("b", 2, policy="spread") == "h1"
    # ...but the router does not route to its replicas yet
    r = Router("least_loaded", fleet=sched)
    engines = {"a": _FakeEngine(5), "b": _FakeEngine(0)}
    assert r.route(_req(), engines) == "a"
    t[0] = 4.9
    assert r.route(_req(), engines) == "a"   # still provisioning
    t[0] = 5.0
    assert sched.host_ready("h1")            # clock passed ready time
    assert sched.report()["booting"] == []   # entry self-cleans
    assert r.route(_req(), engines) == "b"
    sched.check_invariants()


def test_boot_without_delay_is_immediately_routable():
    sched = _fleet({"h0": 8})
    sched.boot_host("h1", HostMemoryBroker(8, clock=_fake_clock()))
    assert sched.host_ready("h1")
    assert sched.report()["booting"] == []
    with pytest.raises(AssertionError):
        sched.boot_host("h2", HostMemoryBroker(8, clock=_fake_clock()),
                        ready_delay=-1.0)


# -------------------------------------------- (c) contention and budget


def test_concurrent_migrations_sharing_an_endpoint_halve_the_pipe():
    """Two overlapping transfers out of one host each see half the
    bandwidth (a retirement stampede slows itself down); a transfer on a
    disjoint endpoint pair is NOT slowed.  latency=0 so the copy walls
    are pure byte terms: 1000 B over 100 B/s = 10 s uncontended, 20 s
    against one contender."""
    sched = _fleet({"h0": 8, "h1": 8, "h2": 8, "h3": 8}, pool_units=4,
                   bandwidth=100.0, latency=0.0)
    for host, keys in (("h0", ("k0", "k1")), ("h2", ("k2",))):
        for k in keys:
            assert sched.brokers[host].snapshot_put(
                k, units=1, payload=object(), nbytes=1000)
    rec_a = sched.migrate_snapshot("k0", "h1")       # clock 1.0: alone
    assert rec_a.copy_seconds == pytest.approx(10.0)
    # clock 2.0: h2 -> h3 shares no endpoint with the in-flight h0 -> h1
    rec_b = sched.migrate_snapshot("k2", "h3")
    assert rec_b.copy_seconds == pytest.approx(10.0)
    # clock 3.0: h0 -> h1 again, against rec_a still in flight (ends at
    # 11.0): one contender, half the pipe, exactly 2x the byte wall
    rec_c = sched.migrate_snapshot("k1", "h1")
    assert rec_c.copy_seconds == pytest.approx(2 * rec_a.copy_seconds)
    sched.check_invariants()


def test_migration_budget_defers_drain_but_never_foreground():
    """The drain budget caps in-flight scale-down bytes so a retirement
    stampede cannot starve foreground restores: the over-budget drain
    transfer returns None (counted, entry left in place); a foreground
    ``ensure_local`` of the SAME entry proceeds immediately."""
    sched = _fleet({"h0": 8, "h1": 8, "h2": 8}, pool_units=4,
                   bandwidth=100.0, latency=0.0, budget_bytes=1500.0)
    for k in ("k0", "k1"):
        assert sched.brokers["h0"].snapshot_put(
            k, units=1, payload=object(), nbytes=1000)
    sched.begin_retire("h0")
    stats = sched.drain_host("h0")
    # k0 fits the budget (0 + 1000 <= 1500); k1 would push in-flight
    # drain bytes to 2000 > 1500: deferred, not discarded
    assert stats == {"migrated": 1, "deferred": 1, "discarded": 0}
    assert sched.migration_deferred == 1
    assert sched.brokers["h0"].snapshot_restorable("k1")
    rec = sched.ensure_local("k1", "h2")     # foreground: never deferred
    assert rec is not None and rec.dst == "h2"
    assert sched.migration_deferred == 1     # unchanged
    assert sched.brokers["h2"].snapshot_restorable("k1")
    sched.check_invariants()


# ------------------------------------- (d) snapshot_affinity cold fallback


def test_snapshot_affinity_cold_fallback_avoids_draining_victim():
    """The routing bug: with NOTHING cached (no warm row, no snapshot),
    the fallback used pure load order and landed invocations on the
    mid-reclaim victim; it now routes through ``_pick`` and counts
    ``drain_avoided``."""
    broker, _ = _mk_async(8, [("a", 2), ("b", 6)], pool_units=8)
    broker.request_grant("b", 3)             # a is now draining
    assert broker.open_order_units("a") > 0
    engines = {"a": _FakeEngine(0), "b": _FakeEngine(5)}
    r = Router("snapshot_affinity", broker=broker)
    assert r.route(_req("html"), engines) == "b"    # dodged victim a
    assert r.drain_avoided == 1
    assert r.warm_routes == 0 and r.snapshot_routes == 0


# ------------------------------------------------ (e) truncation honesty


def test_exhausting_max_ticks_warns_and_flags_truncated():
    broker = HostMemoryBroker(16, async_reclaim=True, clock=_fake_clock())
    a = StubReplica("a", broker, units=4)
    reqs = [Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0)]
    sim = FleetSim({"h0": {"a": a}}, brokers={"h0": broker})
    with pytest.warns(RuntimeWarning, match="truncated"):
        m = sim.run(list(reqs), max_ticks=2)
    assert m["truncated"] is True


def test_completed_run_is_not_truncated():
    broker = HostMemoryBroker(16, async_reclaim=True, clock=_fake_clock())
    a = StubReplica("a", broker, units=4)
    reqs = [Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0)]
    sim = FleetSim({"h0": {"a": a}}, brokers={"h0": broker})
    m = sim.run(list(reqs), max_virtual_s=100)
    assert m["completed"] == 1
    assert m["truncated"] is False


# ------------------------------------------- (f) autoscaled determinism


def test_autoscaled_run_is_bit_identical_for_a_fixed_seed():
    """Boot + retire mid-run are pure functions of (trace, seed): two
    seed-0 runs produce byte-identical rows, lifecycle counters
    included."""
    from repro.cluster.scenarios import run_scenario
    a = json.dumps(run_scenario("autoscale_burst", seed=0),
                   sort_keys=True)
    b = json.dumps(run_scenario("autoscale_burst", seed=0),
                   sort_keys=True)
    assert a == b
    row = json.loads(a)
    assert row["host_boots"] >= 1 and row["host_retires"] >= 1
    assert row["killed"] == 0
