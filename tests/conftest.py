import os
import sys

# tests see ONE device; the 512-device flag is dryrun.py-only by design
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

# jit-heavy modules: every test in these files is tier-"slow" (compilation
# dominates).  ``pytest -m "not slow"`` is the <60s inner loop; the full
# tier-1 command runs everything (see ROADMAP.md "Test tiers").
SLOW_FILES = {
    "test_kernels.py",
    "test_decode_consistency.py",
    "test_archs.py",
    "test_serving.py",
    "test_serving_hedge.py",
    "test_system.py",
    "test_training.py",
    "test_checkpoint.py",
}


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: jit-heavy test (compilation-bound); excluded from the fast "
        "tier via -m 'not slow'")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.fspath.basename in SLOW_FILES:
            item.add_marker(pytest.mark.slow)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
