import os
import sys

# tests see ONE device; the 512-device flag is dryrun.py-only by design
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
