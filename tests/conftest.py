import itertools
import os
import sys
from collections import deque

# tests see ONE device; the 512-device flag is dryrun.py-only by design
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

# jit-heavy modules: every test in these files is tier-"slow" (compilation
# dominates).  ``pytest -m "not slow"`` is the <60s inner loop; the full
# tier-1 command runs everything (see ROADMAP.md "Test tiers").
SLOW_FILES = {
    "test_kernels.py",
    "test_decode_consistency.py",
    "test_archs.py",
    "test_serving.py",
    "test_serving_hedge.py",
    "test_system.py",
    "test_training.py",
    "test_checkpoint.py",
}


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: jit-heavy test (compilation-bound); excluded from the fast "
        "tier via -m 'not slow'")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.fspath.basename in SLOW_FILES:
            item.add_marker(pytest.mark.slow)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


# Shared broker test helpers (imported by the cluster-layer test files —
# one definition so the suites cannot silently diverge in what they
# construct).


def fake_clock():
    """Monotonic deterministic clock: 1.0 per reading."""
    c = itertools.count(1)
    return lambda: float(next(c))


def mk_async_broker(budget, replicas, *, loads=None, clock=None,
                    pool_units=None):
    """Async ``HostMemoryBroker`` + per-replica order queues (the
    engines' order sinks)."""
    from repro.cluster import HostMemoryBroker
    broker = HostMemoryBroker(budget, async_reclaim=True,
                              clock=clock or fake_clock(),
                              snapshot_pool_units=pool_units)
    sinks = {}
    loads = loads or {}
    for rid, units in replicas:
        sinks[rid] = deque()
        broker.register(rid, units, load=lambda r=rid: loads.get(r, 0),
                        order_sink=sinks[rid].append, mode="hotmem")
    return broker, sinks


class StubReplica:
    """Deterministic metadata-only replica, ``ClusterSim``/``FleetSim``-
    compatible: decode costs exactly 1.0 virtual seconds, an order-drain
    chunk 0.25, so the interleaving (and hence the whole schedule) is a
    pure function of the script — no wall-clock measurement anywhere."""

    DECODE_S = 1.0
    DRAIN_S = 0.25

    def __init__(self, rid, broker, units, decode_steps=10):
        from repro.serving.request import State
        self._State = State
        self.rid = rid
        self.broker = broker
        self.units = units
        self.decode_steps = decode_steps
        self.now = 0.0
        self.pending: deque = deque()
        self.active: dict[str, int] = {}
        self.warm: dict[str, list] = {}
        self.done: list = []
        self.events: list[tuple[float, str, int]] = []
        self._orders: deque = deque()
        self._grants: list = []
        broker.register(rid, units, load=self.load,
                        order_sink=self._orders.append, mode="stub")

    def load(self) -> int:
        return len(self.active) + len(self.pending)

    def host_work(self) -> bool:
        return bool(self._orders) or bool(self._grants)

    def request(self, want) -> object:
        g = self.broker.request_grant(self.rid, want)
        self.units += g.granted
        if not g.done or g.available:
            self._grants.append(g)
        return g

    def _tick(self, todo: deque) -> None:
        while todo and todo[0].submit_s <= self.now:
            req = todo.popleft()
            self.active[req.rid] = self.decode_steps
            req.state = self._State.RUNNING
            self.pending.append(req)
        # requester side: claim fills at our own tick boundary
        for g in list(self._grants):
            got = self.broker.claim_grant(g)
            if got:
                self.units += got
                self.events.append((self.now, "fill", got))
            if g.done and g.available == 0:
                self._grants.remove(g)
        # victim side: drain one chunk of the front order per tick
        while self._orders and not self._orders[0].open:
            self._orders.popleft()
        if self._orders:
            o = self._orders[0]
            if self.units > 0:
                self.now += self.DRAIN_S
                acc = self.broker.fulfill_order(o.order_id, 1)
                self.units -= acc
                self.events.append((self.now, "drain", acc))
            else:
                self.broker.cancel_order(o.order_id)
                self._orders.popleft()
        elif self.active:
            self.now += self.DECODE_S
            # record how many host-wide units were still owed while THIS
            # decode step ran: >0 means decode overlapped an open order
            self.events.append((self.now, "decode",
                                self.broker.pending_units()))
            for rid in list(self.active):
                self.active[rid] -= 1
                if self.active[rid] <= 0:
                    del self.active[rid]
                    req = self.pending.popleft()
                    req.state = self._State.DONE
                    req.done_s = self.now
                    self.done.append(req)
        else:
            self.now += 0.1
        self.broker.check_invariants()

    def metrics(self):
        return {"reclaimed_bytes": 0, "migrated_bytes": 0,
                "reclaim_events": sum(1 for e in self.events
                                      if e[1] == "drain")}
