import itertools
import os
import sys
from collections import deque

# tests see ONE device; the 512-device flag is dryrun.py-only by design
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

# jit-heavy modules: every test in these files is tier-"slow" (compilation
# dominates).  ``pytest -m "not slow"`` is the <60s inner loop; the full
# tier-1 command runs everything (see ROADMAP.md "Test tiers").
SLOW_FILES = {
    "test_kernels.py",
    "test_decode_consistency.py",
    "test_archs.py",
    "test_serving.py",
    "test_serving_hedge.py",
    "test_system.py",
    "test_training.py",
    "test_checkpoint.py",
}


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: jit-heavy test (compilation-bound); excluded from the fast "
        "tier via -m 'not slow'")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.fspath.basename in SLOW_FILES:
            item.add_marker(pytest.mark.slow)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


# Shared broker test helpers (imported by the cluster-layer test files —
# one definition so the suites cannot silently diverge in what they
# construct).


def fake_clock():
    """Monotonic deterministic clock: 1.0 per reading."""
    c = itertools.count(1)
    return lambda: float(next(c))


def mk_async_broker(budget, replicas, *, loads=None, clock=None,
                    pool_units=None):
    """Async ``HostMemoryBroker`` + per-replica order queues (the
    engines' order sinks)."""
    from repro.cluster import HostMemoryBroker
    broker = HostMemoryBroker(budget, async_reclaim=True,
                              clock=clock or fake_clock(),
                              snapshot_pool_units=pool_units)
    sinks = {}
    loads = loads or {}
    for rid, units in replicas:
        sinks[rid] = deque()
        broker.register(rid, units, load=lambda r=rid: loads.get(r, 0),
                        order_sink=sinks[rid].append, mode="hotmem")
    return broker, sinks
