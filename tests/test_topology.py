"""Sharded hosts: per-device HBM budgets through broker, ledger, reclaim,
and snapshots.

The tentpole contract under test, layer by layer:

  * ``DeviceTopology`` — the mesh the memory control plane sees: one
    budget column per device, balanced-flow divisibility asserted AT the
    flow;
  * ``BudgetLedger`` — per-device account vectors with the conservation
    law ``free_d + granted_d + escrow_d + snapshot_d == budget_d`` per
    device, proven in the same single ``check`` as the host-wide and
    per-tenant laws;
  * ``HostMemoryBroker`` — shard-coherent reclaim orders: a victim
    drains one unit per shard in lockstep, a partial fill on one device
    stays *incoherent* escrow the requester cannot claim (it may not
    unfence anything), and an order closing with stranded shard fills
    unwinds them to free — loudly asserted if a drain path ever skews
    shards silently;
  * ``SnapshotPool`` / ``FleetScheduler`` — sharded entries carry one
    fragment per device, are restorable only when EVERY fragment is
    present, evict atomically, and pay one link latency per fragment on
    cross-host migration;
  * ``devices=1`` is the exact legacy scalar plane, bit for bit.
"""
import itertools
import random
from collections import deque

import pytest

from repro.cluster import (BudgetLedger, DeviceTopology, HostMemoryBroker,
                           FleetScheduler)
from repro.cluster.scenarios import run_scenario


def fake_clock():
    c = itertools.count(1)
    return lambda: float(next(c))


def mk_mesh_broker(rows, devices, replicas, *, pool_rows=None):
    """Uniform ``devices``-wide broker with ``rows`` rows of budget; each
    replica spec is (rid, start_rows)."""
    topo = DeviceTopology.uniform(rows * devices, devices)
    broker = HostMemoryBroker(
        async_reclaim=True, clock=fake_clock(),
        snapshot_pool_units=pool_rows * devices if pool_rows else None,
        topology=topo)
    sinks = {}
    for rid, start in replicas:
        sinks[rid] = deque()
        broker.register(rid, start * devices, load=lambda: 0,
                        order_sink=sinks[rid].append, mode="hotmem",
                        shards=devices)
    return broker, sinks


# ------------------------------------------------------------- topology


def test_topology_constructors_and_guards():
    t = DeviceTopology.uniform(24, 4)
    assert t.n_devices == 4 and t.total_units == 24
    assert t.budgets == (6, 6, 6, 6) and t.uniform_budget
    assert t.assert_balanced(8, "test") == 2
    s = DeviceTopology.single(7)
    assert s.n_devices == 1 and s.assert_balanced(5, "x") == 5
    with pytest.raises(AssertionError):
        DeviceTopology.uniform(10, 4)            # not divisible
    with pytest.raises(AssertionError):
        t.assert_balanced(6, "unbalanced")       # 6 % 4 != 0
    with pytest.raises(AssertionError):
        DeviceTopology(budgets=())
    rep = t.report()
    assert rep["devices"] == 4 and rep["total_units"] == 24


def test_broker_register_shards_must_span_the_mesh():
    broker, _ = mk_mesh_broker(8, 4, [])
    with pytest.raises(AssertionError):
        broker.register("r", 4, shards=2)        # half-mesh replica
    with pytest.raises(AssertionError):
        broker.register("r", 6, shards=4)        # 6 units don't stripe
    broker.register("r", 8, shards=4)
    assert broker.ledger.granted_dev("r") == (2, 2, 2, 2)


def test_balanced_flow_asserted_at_the_flow():
    broker, _ = mk_mesh_broker(8, 4, [("r", 2)])
    with pytest.raises(AssertionError):
        broker.request_grant("r", 6)             # 6 % 4 != 0
    with pytest.raises(AssertionError):
        broker.release_units("r", 3)
    g = broker.request_grant("r", 8)             # balanced: fine
    assert g.granted == 8
    broker.check_invariants()


# ------------------------------------------- per-device conservation law


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("devices", [2, 4])
def test_ledger_per_device_conservation_seeded(seed, devices):
    """Random balanced + single-device flows: the per-device law (and its
    host/tenant sums) hold after EVERY op, and the device report columns
    always partition each device's budget."""
    rng = random.Random(seed)
    n = devices
    led = BudgetLedger(topology=DeviceTopology.uniform(8 * n, n))
    rids = ["a", "b"]
    for r in rids:
        led.carve(r, 2 * n)
    led.check()
    for _ in range(80):
        r = rng.choice(rids)
        kind = rng.choice(("take", "release", "escrow_in", "escrow_out",
                           "shard_fill", "snap_charge", "snap_credit"))
        if kind == "take":
            got = led.take_free(r, rng.randint(0, 4) * n)
            assert got % n == 0
        elif kind == "release":
            cov = min(led.granted_dev(r))
            if cov:
                led.release(r, rng.randint(1, cov) * n)
        elif kind == "escrow_in":
            cov = min(led.granted_dev(r))
            if cov:
                led.escrow_fill(r, rng.randint(1, cov) * n, requester=r)
        elif kind == "escrow_out":
            cov = min(e["escrow"] for e in led.device_report())
            if cov:
                led.escrow_claim(r, rng.randint(1, cov) * n)
        elif kind == "shard_fill":
            d = rng.randrange(n)
            if led.granted_dev(r)[d]:
                led.escrow_fill(r, 1, requester=r, dev=d)
                led.escrow_release(1, requester=r, dev=d)
        elif kind == "snap_charge":
            cov = led.balanced_free()
            if cov:
                led.snapshot_charge(rng.randint(1, cov // n) * n)
        elif kind == "snap_credit":
            cov = min(e["snapshot"] for e in led.device_report())
            if cov:
                led.snapshot_credit(rng.randint(1, cov) * n)
        led.check()
        for d, col in enumerate(led.device_report()):
            assert col["free"] + col["granted"] + col["escrow"] \
                + col["snapshot"] == col["budget"] == 8, d


def test_devices1_topology_is_the_exact_scalar_ledger():
    """A 1-device topology must be arithmetically indistinguishable from
    the legacy scalar ledger on any op stream (the bit-identity anchor
    for every pre-mesh trace)."""
    scalar = BudgetLedger(32)
    mesh = BudgetLedger(topology=DeviceTopology.single(32))
    rng = random.Random(0)
    for led in (scalar, mesh):
        led.carve("a", 5)
        led.carve("b", 3)
    for _ in range(120):
        kind = rng.choice(("take", "release", "escrow_in", "escrow_out"))
        r = rng.choice(("a", "b"))
        amt = rng.randint(1, 6)
        for led in (scalar, mesh):
            if kind == "take":
                led.take_free(r, amt)
            elif kind == "release" and led.granted[r]:
                led.release(r, 1 + (amt - 1) % led.granted[r])
            elif kind == "escrow_in" and led.granted[r]:
                led.escrow_fill(r, 1 + (amt - 1) % led.granted[r])
            elif kind == "escrow_out" and led.escrow_units:
                led.escrow_claim(r, 1 + (amt - 1) % led.escrow_units)
            led.check()
        assert scalar.granted == mesh.granted
        assert scalar.free_units == mesh.free_units
        assert scalar.escrow_units == mesh.escrow_units
        assert mesh.balanced_free() == mesh.free_units


# --------------------------------------------- shard-coherent reclaim


def _pressured_mesh(devices=4):
    """Victim holding almost the whole mesh + a requester whose grant
    forces one reclaim order of exactly one row (one unit per shard)."""
    broker, sinks = mk_mesh_broker(6, devices, [("v", 5), ("q", 0)])
    g = broker.request_grant("q", 2 * devices)   # 1 row free, 1 row owed
    assert g.granted == devices and g.pending == devices
    (order,) = sinks["v"]
    assert order.shards == devices and order.per_shard == 1
    return broker, g, order


def test_partial_shard_fill_stays_incoherent_and_unclaimable():
    """Fills on SOME devices must not unfence the requester: the stripe
    is claimable only once the LAST shard lands."""
    broker, g, order = _pressured_mesh()
    for d in range(3):
        assert broker.fulfill_order(order.order_id, 1, shard=d) == 1
        assert g.available == 0 and g.incoherent == d + 1
        assert broker.claim_grant(g) == 0        # nothing unfenced
        broker.check_invariants()
    assert order.coherent_filled == 0 and order.open
    assert broker.fulfill_order(order.order_id, 1, shard=3) == 1
    assert g.incoherent == 0 and g.available == 4
    assert not order.open                        # filled in lockstep
    assert broker.claim_grant(g) == 4            # the whole stripe at once
    assert broker.ledger.granted_dev("q") == (2, 2, 2, 2)
    assert broker.ledger.granted_dev("v") == (4, 4, 4, 4)
    broker.check_invariants()


def test_overdrain_on_one_shard_is_clamped():
    broker, g, order = _pressured_mesh()
    assert broker.fulfill_order(order.order_id, 3, shard=0) == 1
    assert broker.fulfill_order(order.order_id, 1, shard=0) == 0
    broker.check_invariants()


def test_cancel_unwinds_stranded_shard_fills_to_free():
    """An order canceled after a partial stripe: the stranded fill cannot
    ever become claimable, so close-time unwind returns it to the free
    pool (on ITS device) and counts it denied."""
    broker, g, order = _pressured_mesh()
    assert broker.fulfill_order(order.order_id, 1, shard=0) == 1
    denied0 = broker.denied_units
    broker.cancel_order(order.order_id)
    assert not order.open
    assert g.incoherent == 0 and g.available == 0 and g.done
    # shard 0's stranded unit went escrow -> free on device 0 alone
    assert [broker.ledger.free_dev(d) for d in range(4)] == [1, 0, 0, 0]
    assert broker.denied_units == denied0 + 3 + 1   # remainder + stranded
    assert broker.claim_grant(g) == 0
    broker.check_invariants()
    broker.ledger.check()


def test_loud_assert_on_shard_incoherent_close():
    """Satellite regression: a drain path that closes an order while a
    grant still holds incoherent escrow (some shards filled, siblings
    canceled WITHOUT the close-time unwind) must trip ``check_invariants``
    loudly — not leak the units silently."""
    broker, g, order = _pressured_mesh()
    assert broker.fulfill_order(order.order_id, 1, shard=0) == 1
    assert g.incoherent == 1
    # white-box: force-close the order behind the broker's back, the way
    # a buggy driver would — scalar and vector cancels kept consistent so
    # only the coherence law is violated
    for d in range(order.shards):
        rem = order.shard_remaining(d)
        order.canceled_by_shard[d] += rem
        order.canceled += rem
    assert not order.open
    with pytest.raises(AssertionError, match="shard-incoherent drain"):
        broker.check_invariants()


def test_natural_release_fills_whole_stripes_only():
    """A victim's natural release routes into its open order in whole
    stripes (floored to the shard multiple), never skewing shards."""
    broker, g, order = _pressured_mesh()
    broker.release_units("v", 4)                 # one row back
    assert order.filled == 4 and not order.open
    assert list(order.filled_by_shard) == [1, 1, 1, 1]
    assert g.available == 4 and g.incoherent == 0
    assert broker.claim_grant(g) == 4
    broker.check_invariants()


# ------------------------------------------------- sharded snapshots


def test_sharded_snapshot_restorable_only_with_every_fragment():
    broker, _ = mk_mesh_broker(6, 4, [("r", 2)], pool_rows=2)
    frags = tuple(("kv", "f", d) for d in range(4))
    assert broker.snapshot_put("whole", units=4, payload=("kv", "f"),
                               nbytes=64, replica_id="r", fragments=frags)
    assert broker.snapshot_restorable("whole")
    # a missing fragment: present in the pool, NOT restorable
    assert broker.snapshot_put("holey", units=4, payload=("kv", "g"),
                               nbytes=64, replica_id="r",
                               fragments=(("kv", "g", 0), None,
                                          ("kv", "g", 2), ("kv", "g", 3)))
    assert broker.snapshot_available("holey")
    assert not broker.snapshot_restorable("holey")
    broker.check_invariants()
    # eviction is atomic: the whole striped charge returns at once
    free_before = [broker.ledger.free_dev(d) for d in range(4)]
    assert broker.snapshot_drop("whole") == 4
    assert [broker.ledger.free_dev(d) for d in range(4)] \
        == [f + 1 for f in free_before]
    broker.check_invariants()


def test_sharded_snapshot_charge_must_stripe():
    broker, _ = mk_mesh_broker(6, 4, [("r", 2)], pool_rows=2)
    with pytest.raises(AssertionError):
        broker.snapshot_put("bad", units=6, payload=("kv", "x"),
                            nbytes=64, replica_id="r",
                            fragments=tuple(range(4)))   # 6 % 4 != 0


def test_migration_pays_link_latency_per_fragment():
    devices = 4
    topo = DeviceTopology.uniform(6 * devices, devices)
    sched = FleetScheduler(bandwidth_bytes_per_s=1e6, link_latency_s=1e-3)
    for h in ("h0", "h1"):
        sched.add_host(h, HostMemoryBroker(
            async_reclaim=True, clock=fake_clock(),
            snapshot_pool_units=2 * devices, topology=topo))
    frags = tuple(("kv", "f", d) for d in range(devices))
    assert sched.brokers["h0"].snapshot_put(
        "sharded", units=devices, payload=("kv", "f"), nbytes=2000,
        replica_id="r", fragments=frags)
    rec = sched.migrate_snapshot("sharded", "h1")
    assert rec is not None
    # one latency per fragment + the byte wall over the shared pipe
    assert rec.copy_seconds == pytest.approx(devices * 1e-3 + 2000 / 1e6)
    assert sched.brokers["h1"].snapshot_restorable("sharded")
    snap = sched.brokers["h1"].snapshots.peek("sharded")
    assert snap.fragments == frags               # fragments travel intact
    sched.check_invariants()
    # the unsharded case pays exactly ONE latency; on this scheduler's
    # frozen default clock the sharded transfer above never finishes, so
    # it still occupies both NICs and halves the second transfer's pipe
    # (latency is propagation — it does not contend)
    assert sched.brokers["h0"].snapshot_put(
        "flat", units=devices, payload=("kv", "g"), nbytes=2000,
        replica_id="r")
    rec2 = sched.migrate_snapshot("flat", "h1")
    assert rec2.copy_seconds == pytest.approx(1e-3 + 2 * 2000 / 1e6)


# -------------------------------------------------- scenario-level pin


def test_mesh_scenario_mirrors_the_scalar_scaledown_exactly():
    """``mesh_reclaim`` is the scaledown workload with every row backed
    by a 4-unit stripe: all counts and every virtual time must equal the
    scalar scenario exactly (the whole schedule is devices-invariant),
    unit totals scale by exactly 4, and the final per-device free
    vectors are balanced."""
    mesh = run_scenario("mesh_reclaim", seed=0)
    scalar = run_scenario("scaledown_burst", seed=0)
    for k in ("requests", "completed", "killed", "warm_starts",
              "restore_starts", "remote_restore_starts", "cold_starts",
              "reclaim_orders", "warm_ttft_ms", "restore_ttft_ms",
              "cold_ttft_ms", "stall_p99_ms", "host_seconds", "routes"):
        assert mesh[k] == scalar[k], k
    assert mesh["order_units"] == scalar["order_units"] * 4
    assert mesh["free_units_end"]["h0"] == scalar["free_units_end"]["h0"] * 4
    (vec,) = mesh["device_units_end"].values()
    assert len(vec) == 4 and len(set(vec)) == 1     # balanced at rest
