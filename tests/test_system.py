"""End-to-end behaviour: the paper's headline claims measured on this
system (tiny configs, real device ops).  These are the pass/fail versions
of the benchmarks in ``benchmarks/``."""
import jax
import pytest

from repro.configs.base import get_config, reduced
from repro.core.arena import ArenaSpec
from repro.core.elastic import ElasticArena
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen2-7b"))
    spec = ArenaSpec.from_model(cfg, partition_tokens=256, n_partitions=16,
                                block_tokens=32)
    return cfg, spec


def _fill(arena, n, tokens):
    for i in range(n):
        arena.admit(f"r{i}")
        arena.on_tokens(f"r{i}", tokens)


def test_c1_reclaim_zero_migration(setup):
    """C1 (paper Fig. 5): HotMem reclaim does no data movement; vanilla
    must copy. Compare *bytes moved* — the hardware-independent claim."""
    cfg, spec = setup
    import jax.numpy as jnp
    pool = [jnp.zeros((spec.n_blocks, spec.block_tokens, 64),
                      jnp.bfloat16)]
    va = ElasticArena(cfg, spec, "vanilla", caches=pool, seed=0)
    _fill(va, 12, 256)
    for i in (1, 4, 7, 9, 10, 11):
        va.finish(f"r{i}")
    ev_v = va.unplug(6 * spec.blocks_per_partition)

    hm = ElasticArena(cfg, spec, "hotmem")
    _fill(hm, 12, 256)
    for i in (1, 4, 7, 9, 10, 11):
        hm.finish(f"r{i}")
    ev_h = hm.unplug(6)
    assert ev_h.migrated_bytes == 0
    assert ev_v.migrated_bytes > 0
    assert ev_h.reclaimed_bytes > 0


def test_c2_reclaim_flat_vs_occupancy(setup):
    """C2 (paper Fig. 6): HotMem reclaim work is independent of occupancy;
    vanilla migration volume grows with it."""
    cfg, spec = setup
    v_moves, h_moves = [], []
    for occupancy in (2, 6, 10):
        va = ElasticArena(cfg, spec, "vanilla", seed=1)
        _fill(va, occupancy, 256)
        k, moves = va.manager.shrink_plan(4 * spec.blocks_per_partition)
        v_moves.append(len(moves))
        hm = ElasticArena(cfg, spec, "hotmem")
        _fill(hm, occupancy, 256)
        h_moves.append(hm.unplug(2).migrated_blocks)
    assert h_moves == [0, 0, 0]
    assert v_moves[-1] > v_moves[0]


def test_shared_state_untouched_by_resize(setup):
    """N:1 sharing: weights (the 'shared partition') are untouched by
    plug/unplug — only per-request partitions move."""
    cfg, spec = setup
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    before = jax.tree.leaves(params)[0].copy()
    hm = ElasticArena(cfg, spec, "hotmem")
    _fill(hm, 4, 128)
    hm.unplug(4)
    after = jax.tree.leaves(params)[0]
    assert bool((before == after).all())
