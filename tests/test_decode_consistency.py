"""System-level oracle: prefill-then-decode must match the full-sequence
forward for every architecture (validates cache semantics end to end —
ring buffers, SSD state handoff, RG-LRU state, cross-attention caches,
per-row positions)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_archs, reduced
from repro.models import model as M

B, S = 2, 12


def _batch(cfg, rng, toks):
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_src_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            rng, (B, cfg.vision_stub_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch, rng):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, rng)
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    full = M.forward_train(cfg, params, _batch(cfg, rng, toks),
                           remat=False)[:, S].astype(jnp.float32)
    caches = M.init_caches(cfg, B, 32)
    _, caches = M.prefill(cfg, params, _batch(cfg, rng, toks[:, :S]),
                          caches)
    lg, _ = M.decode_step(cfg, params, toks[:, S:S + 1],
                          jnp.full((B,), S, jnp.int32), caches)
    err = float(jnp.max(jnp.abs(full - lg.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    # MoE: top-k gating sits near decision boundaries at reduced width, so
    # tiny train-vs-decode numeric drift gets amplified through expert mix
    tol = 0.08 if cfg.family == "moe" else 0.05
    assert err / scale < tol, f"{arch}: rel err {err / scale}"


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "recurrentgemma-2b",
                                  "gemma2-9b"])
def test_ring_cache_beyond_window(arch, rng):
    """Windowed archs: decoding far past the window stays finite and the
    ring cache keeps only the window (long_500k viability)."""
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, rng)
    caches = M.init_caches(cfg, B, 16)
    toks = jax.random.randint(rng, (B, 12), 0, cfg.vocab_size)
    lg, caches = M.prefill(cfg, params, _batch(cfg, rng, toks), caches)
    for i in range(20):                      # run well past window=8
        nxt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        lg, caches = M.decode_step(cfg, params, nxt,
                                   jnp.full((B,), 12 + i, jnp.int32),
                                   caches)
        assert not bool(jnp.isnan(lg).any())


def test_continuous_batching_rows_independent(rng):
    """Per-row positions: decoding row A must not disturb row B — the
    partition-isolation property HotMem relies on."""
    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, rng)
    toks = jax.random.randint(rng, (2, 9), 0, cfg.vocab_size)
    caches = M.init_caches(cfg, 2, 32)
    lg, caches = M.prefill(cfg, params, {"tokens": toks[:, :8]}, caches)
    # advance only row 0 three times; row 1 stays at position 8
    cur = lg
    for i in range(3):
        step_tok = jnp.stack([toks[0, 8], toks[1, 8]])[:, None]
        pos = jnp.asarray([8 + i, 8], jnp.int32)
        cur, caches = M.decode_step(cfg, params, step_tok, pos, caches)
    # row 1's logits at its position should equal a fresh decode at pos 8
    fresh_caches = M.init_caches(cfg, 2, 32)
    _, fresh_caches = M.prefill(cfg, params, {"tokens": toks[:, :8]},
                                fresh_caches)
    fresh, _ = M.decode_step(cfg, params,
                             jnp.stack([toks[0, 8], toks[1, 8]])[:, None],
                             jnp.asarray([8, 8], jnp.int32), fresh_caches)
    err = float(jnp.max(jnp.abs(
        cur[1].astype(jnp.float32) - fresh[1].astype(jnp.float32))))
    assert err < 0.35, err
