"""Host-memory snapshot pool: warm-restart persistence + the
squeeze-first reclaim rule.

Fast tests drive the pool and broker as pure metadata (every event
followed by ``check_invariants``, which now enforces the extended
conservation law ``free + granted + escrow + snapshot_units == budget``).
The properties pinned down:

  (a) under pressure the broker squeezes snapshot units FIRST — while the
      pool can cover a grant, zero bytes migrate and no ``ReclaimOrder``
      reaches any replica;
  (b) pool bookkeeping: LRU eviction, same-key replacement, cap and
      free-pool bounds, lookup recency;
  (c) ``snapshot_affinity`` routing: warm row > host-wide snapshot > any
      replica (dodging mid-reclaim victims).

The ``slow``-marked tests run a real ``ServeEngine``: capture on
keep-alive expiry, restore on admission (with the cold/warm/restore cost
ordering), bit-identity of a restored partition vs the warm-adopt path,
the row-skew decode assertion, and the warm-hit accounting fix
(route-time prediction vs engine-side outcome).
"""
from collections import deque

import pytest

from repro.cluster import HostMemoryBroker, Router, SnapshotPool
from repro.core.arena import ArenaSpec
from repro.serving.request import PROFILES, Request


from conftest import fake_clock as _fake_clock, \
    mk_async_broker as _mk_async


# ------------------------------------------- (a) squeeze-first reclaim


def test_squeeze_covers_grant_without_any_reclaim_order():
    """THE acceptance property: while the pool can cover the deficit, the
    grant is filled by dropping snapshots — metadata-only (no steal, no
    migration, no order sink called) — and the requester never stalls."""
    broker, sinks = _mk_async(16, [("a", 4), ("b", 4)], pool_units=8)
    broker.check_invariants()
    assert broker.snapshot_put("cnn", units=3, nbytes=100)
    broker.check_invariants()
    assert broker.snapshot_put("bert", units=3, nbytes=100)
    broker.check_invariants()
    assert broker.free_units == 2 and broker.snapshot_units() == 6

    g = broker.request_grant("a", 6)           # free 2 + squeeze the rest
    broker.check_invariants()
    assert g.granted == 6 and g.pending == 0 and g.done
    assert not sinks["a"] and not sinks["b"], "ReclaimOrder issued while " \
        "the snapshot pool could cover the grant"
    assert not broker.steal_log                # zero migration, zero steal
    assert broker.request_stalls == []         # no reclaim engaged: the
    #                                            stall series stays empty,
    #                                            same as a free-pool fill
    assert len(broker.squeeze_log) == 2        # cnn then bert (LRU order)
    assert [r.key for r in broker.squeeze_log] == ["cnn", "bert"]
    assert sum(r.units for r in broker.squeeze_log) == 6
    assert broker.snapshot_units() == 0
    rep = broker.report()
    assert rep["snapshot_squeezes"] == 2 and rep["squeezed_units"] == 6


def test_squeeze_partial_then_orders_for_remainder():
    """A pool that covers only part of the deficit is drained first; the
    reclaim orders that follow are sized to the REMAINDER only."""
    broker, sinks = _mk_async(14, [("a", 4), ("b", 8)], pool_units=4)
    assert broker.snapshot_put("cnn", units=2)
    broker.check_invariants()
    assert broker.free_units == 0
    g = broker.request_grant("a", 5)           # squeeze 2, order 3
    broker.check_invariants()
    assert g.granted == 2 and g.pending == 3
    assert len(sinks["b"]) == 1 and sinks["b"][0].units == 3
    assert broker.snapshot_units() == 0
    assert [r.units for r in broker.squeeze_log] == [2]


def test_sync_broker_squeezes_before_inline_steal():
    """Sync mode: the pool absorbs the pressure before any victim's
    reclaim callback runs (and the requester-visible stall stays 0)."""
    calls = []

    def reclaim(k):
        calls.append(k)
        return min(k, 4), None

    broker = HostMemoryBroker(12, clock=_fake_clock(),
                              snapshot_pool_units=6)
    broker.register("a", 4)
    broker.register("b", 4, reclaim=reclaim, load=lambda: 0)
    assert broker.snapshot_put("cnn", units=4)     # free 4 -> 0
    broker.check_invariants()
    g = broker.request_grant("a", 6)           # squeezed 4 + stolen 2
    broker.check_invariants()
    # pool covered 4 of the 6; only the remaining 2 engaged the victim
    assert g.granted == 6
    assert calls == [2]
    assert broker.snapshot_units() == 0
    # a fully pool-covered request never invokes the callback at all
    broker.release_units("a", 2)               # free 2
    assert broker.snapshot_put("bert", units=2)
    g2 = broker.request_grant("a", 2)          # free 0: pure squeeze
    broker.check_invariants()
    assert g2.granted == 2 and calls == [2] and g2.stall_seconds == 0.0


def test_pool_fenced_during_inline_steal():
    """Mid-sync-steal, every unit a victim surrenders already belongs to
    the open grant: a victim's eviction path must not divert free units
    into a snapshot capture (``snapshot_room``/``snapshot_put`` decline
    while the inline reclaim is in flight), so the requester is never
    short-changed by its own steal."""
    broker = HostMemoryBroker(12, clock=_fake_clock(),
                              snapshot_pool_units=6)

    def reclaim(k):
        # victim tries to persist a warm partition mid-steal (what
        # _evict_warm_suffix would attempt): the fenced pool declines
        assert not broker.snapshot_room("cnn", 2)
        assert not broker.snapshot_put("cnn", units=2)
        return min(k, 4), None

    broker.register("a", 4)
    broker.register("b", 8, reclaim=reclaim, load=lambda: 0)
    g = broker.request_grant("a", 4)           # free 0: inline steal
    broker.check_invariants()
    assert g.granted == 4                      # nothing was diverted
    assert broker.snapshot_units() == 0
    # the fence lifts with the steal: the same put succeeds afterwards
    broker.release_units("a", 2)
    assert broker.snapshot_put("cnn", units=2)
    broker.check_invariants()


def test_register_squeezes_pool_for_boot():
    """A booting VM outranks cached warm-restart state: registration
    squeezes the pool when the free pool alone cannot cover the plug."""
    broker = HostMemoryBroker(8, clock=_fake_clock(), snapshot_pool_units=8)
    broker.register("a", 4)
    assert broker.snapshot_put("cnn", units=4)
    broker.check_invariants()
    assert broker.free_units == 0
    broker.register("b", 4)                    # squeezed, not refused
    broker.check_invariants()
    assert broker.granted == {"a": 4, "b": 4}
    assert broker.snapshot_units() == 0
    assert [r.requester for r in broker.squeeze_log] == ["b"]


# --------------------------------------------------- (b) pool bookkeeping


def test_snapshot_put_replaces_same_key_and_respects_cap():
    broker = HostMemoryBroker(10, clock=_fake_clock(),
                              snapshot_pool_units=4)
    broker.register("a", 2)                    # free 8
    assert broker.snapshot_put("cnn", units=2)
    broker.check_invariants()
    assert broker.snapshot_put("cnn", units=3)     # replace, not stack
    broker.check_invariants()
    assert broker.snapshot_units() == 3
    assert broker.snapshots.replaced == 1
    # cap 4: inserting bert(2) evicts LRU (cnn) rather than overflowing
    assert broker.snapshot_put("bert", units=2)
    broker.check_invariants()
    assert broker.snapshot_units() == 2
    assert not broker.snapshot_available("cnn")
    assert broker.snapshot_available("bert")
    # over the cap entirely: rejected, nothing mutated
    before = broker.report()["snapshots"]
    assert not broker.snapshot_put("html", units=5)
    broker.check_invariants()
    assert broker.report()["snapshots"] == before


def test_snapshot_put_bounded_by_free_plus_pool():
    """Insertion only spends free units (plus what eviction recovers) —
    it can never create pressure on the replicas."""
    broker = HostMemoryBroker(10, clock=_fake_clock(),
                              snapshot_pool_units=10)
    broker.register("a", 7)                    # free 3
    assert broker.snapshot_put("cnn", units=2)
    broker.check_invariants()
    # free 1, pool 2: a 4-unit snapshot cannot fit anywhere
    assert not broker.snapshot_room("html", 4)
    assert not broker.snapshot_put("html", units=4)
    broker.check_invariants()
    assert broker.snapshot_available("cnn")    # untouched by the refusal
    # 3 units fit by evicting the LRU entry
    assert broker.snapshot_put("html", units=3)
    broker.check_invariants()
    assert not broker.snapshot_available("cnn")
    assert broker.snapshot_units() == 3


def test_snapshot_lookup_refreshes_lru_order():
    broker = HostMemoryBroker(10, clock=_fake_clock(),
                              snapshot_pool_units=10)
    broker.register("a", 4)
    assert broker.snapshot_put("cnn", units=2)
    assert broker.snapshot_put("bert", units=2)
    snap = broker.snapshot_lookup("cnn")       # touch: cnn becomes MRU
    assert snap is not None and snap.restores == 1
    broker._squeeze_snapshots(1, requester="a")
    broker.check_invariants()
    assert broker.snapshot_available("cnn")    # survivor: recently used
    assert not broker.snapshot_available("bert")
    pool = broker.snapshots
    assert pool.hits == 1
    assert broker.snapshot_lookup("nope") is None
    assert pool.misses == 1


def test_snapshot_drop_and_disabled_pool():
    broker = HostMemoryBroker(10, clock=_fake_clock(),
                              snapshot_pool_units=10)
    broker.register("a", 4)
    assert broker.snapshot_put("cnn", units=2)
    assert broker.snapshot_drop("cnn") == 2
    broker.check_invariants()
    assert broker.free_units == 6 and broker.snapshot_units() == 0
    assert broker.snapshot_drop("cnn") == 0
    # default broker: pool disabled, every verb is a cheap no
    plain = HostMemoryBroker(10)
    plain.register("a", 4)
    assert not plain.snapshot_room("cnn", 1)
    assert not plain.snapshot_put("cnn", units=1)
    assert plain.snapshot_lookup("cnn") is None
    assert not plain.snapshot_available("cnn")
    assert plain.snapshot_units() == 0
    plain.check_invariants()


def test_pool_unit_invariants_direct():
    pool = SnapshotPool(max_units=4)
    with pytest.raises(AssertionError):
        SnapshotPool(max_units=0)
    assert pool.evict_lru() is None
    assert pool.drop("nope") == 0
    assert len(pool) == 0 and pool.units == 0
    pool.check_invariants()


# -------------------------------------------------- (c) snapshot routing


class _FakeEngine:
    def __init__(self, load, warm=()):
        self._load = load
        self.warm = {name: [(0.0, "rid", 0)] for name in warm}

    def load(self):
        return self._load


def _req(profile):
    return Request(rid="x", profile=PROFILES[profile], submit_s=0.0)


def test_snapshot_affinity_warm_beats_snapshot():
    broker = HostMemoryBroker(10, clock=_fake_clock(),
                              snapshot_pool_units=10)
    broker.register("a", 2)
    broker.register("b", 2)
    assert broker.snapshot_put("cnn", units=2, payload=object())
    engines = {"a": _FakeEngine(0), "b": _FakeEngine(5, warm=("cnn",))}
    r = Router("snapshot_affinity", broker=broker)
    assert r.route(_req("cnn"), engines) == "b"     # warm row first
    assert r.warm_routes == 1 and r.snapshot_routes == 0


def test_snapshot_affinity_snapshot_then_fallback():
    broker = HostMemoryBroker(10, clock=_fake_clock(),
                              snapshot_pool_units=10)
    broker.register("a", 2)
    broker.register("b", 2)
    assert broker.snapshot_put("bert", units=2, payload=object())
    engines = {"a": _FakeEngine(1), "b": _FakeEngine(4)}
    r = Router("snapshot_affinity", broker=broker)
    # pool is host-wide: any replica restores; least-loaded wins
    assert r.route(_req("bert"), engines) == "a"
    assert r.snapshot_routes == 1
    # no warm row, no snapshot: plain least-loaded, not counted
    assert r.route(_req("html"), engines) == "a"
    assert r.snapshot_routes == 1 and r.warm_routes == 0


def test_snapshot_affinity_dodges_draining_victim():
    """A restore adds memory demand — never aim it at a replica that is
    mid-reclaim (open order), even if that replica is less loaded."""
    broker, sinks = _mk_async(8, [("a", 2), ("b", 6)], pool_units=8)
    # b requests more than free: an order lands on a (a is now draining)
    broker.request_grant("b", 3)
    assert broker.open_order_units("a") > 0
    # b's workload later shrinks; a warm expiry then pools a snapshot
    # while a's order is still open
    broker.release_units("b", 2)
    assert broker.snapshot_put("cnn", units=1, payload=object())
    engines = {"a": _FakeEngine(0), "b": _FakeEngine(5)}
    r = Router("snapshot_affinity", broker=broker)
    assert r.route(_req("cnn"), engines) == "b"     # dodges the victim
    assert r.snapshot_routes == 1


def test_metadata_only_entry_present_but_not_restorable():
    """A payload-less entry (non-engine producer) is *present* in the
    pool but can never serve a restore: the restorable probe rejects it
    without touching the hit counter or the MRU slot, and the router
    falls back to plain least-loaded instead of predicting an impossible
    restore."""
    broker = HostMemoryBroker(10, clock=_fake_clock(),
                              snapshot_pool_units=10)
    broker.register("a", 2)
    broker.register("b", 2)
    assert broker.snapshot_put("cnn", units=2)              # metadata-only
    assert broker.snapshot_put("bert", units=2, payload=object())
    assert broker.snapshot_available("cnn")
    assert not broker.snapshot_restorable("cnn")
    assert broker.snapshot_restorable("bert")
    # probing never refreshes recency or counts a hit
    for _ in range(3):
        broker.snapshot_restorable("cnn")
    assert broker.snapshots.hits == 0
    assert broker.snapshots.keys()[0] == "cnn"  # still first in LRU order
    engines = {"a": _FakeEngine(1), "b": _FakeEngine(4)}
    r = Router("snapshot_affinity", broker=broker)
    assert r.route(_req("cnn"), engines) == "a"  # plain least-loaded
    assert r.snapshot_routes == 0                # no impossible prediction


# --------------------------------------------- engine integration (slow)


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import model as M
    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    return cfg, params, spec


def _run_one(eng, rid, prof="cnn"):
    eng.submit(Request(rid=rid, profile=PROFILES[prof], submit_s=eng.now))
    empty = deque()
    while eng.active or eng.pending:
        eng._tick(empty)
    return next(r for r in eng.done if r.rid == rid)


@pytest.mark.slow
def test_snapshot_capture_and_restore_end_to_end(setup):
    """Cold -> warm -> expiry (capture) -> restore, on one engine: the
    pool holds the expired container's prefix KV, a later invocation of
    the same function restores instead of prefilling, and the three start
    paths cost prefill > restore > warm (zero)."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                      seed=0, broker=broker, replica_id="A")
    _run_one(eng, "c0")                        # cold (prefill)
    _run_one(eng, "w0")                        # warm adopt (same profile)
    assert eng.cold_starts == 1 and eng.warm_starts == 1
    warm_evs = [e for e in eng.events if e.kind == "warm_start"]
    assert len(warm_evs) == 1 and warm_evs[0].wall_s == 0.0

    eng.now += eng.keep_alive + 1.0            # container expires
    eng._recycle_idle()
    broker.check_invariants()
    assert broker.snapshot_available("cnn")
    snap_evs = [e for e in eng.events if e.kind == "snapshot"]
    assert len(snap_evs) == 1
    assert snap_evs[0].detail["bytes"] > 0 and snap_evs[0].wall_s > 0
    assert broker.snapshot_units() == bpp      # one partition charged

    _run_one(eng, "s0")                        # restore from the pool
    broker.check_invariants()
    assert eng.restore_starts == 1 and eng.cold_starts == 1
    rest_evs = [e for e in eng.events if e.kind == "restore"]
    assert len(rest_evs) == 1 and rest_evs[0].detail["key"] == "cnn"
    # cost ordering: prefill > restore copy > warm adopt (zero)
    prefill_wall = max(e.wall_s for e in eng.events if e.kind == "prefill")
    assert 0.0 < rest_evs[0].wall_s < prefill_wall
    # the snapshot stays pooled: a second post-expiry invocation restores
    # again (one capture serves every later cold start of the profile)
    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()
    _run_one(eng, "s1")
    assert eng.restore_starts == 2
    m = eng.metrics()
    assert m["warm_starts"] == 1 and m["restore_starts"] == 2
    assert m["cold_starts"] == 1


@pytest.mark.slow
def test_restore_bit_identical_to_warm_adopt(setup):
    """The restored partition is byte-for-byte the state a warm adopt
    would have reused, so decode from it is bit-identical."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import model as M
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                      seed=0, broker=broker, replica_id="A")
    _run_one(eng, "c0")
    (_, _, row) = eng.warm["cnn"][0]
    warm_state = jax.device_get(M.cache_read_row(eng.caches, row))

    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()                        # capture on expiry
    snap = broker.snapshots.peek("cnn")
    assert snap is not None
    # the staged payload is one contiguous blob; carving it (zero-copy
    # views) must give back exactly the warm partition's leaves
    for a, b in zip(jax.tree.leaves(warm_state),
                    jax.tree.leaves(snap.payload.tree())):
        assert a.dtype == b.dtype and np.array_equal(a, b)

    # restore lands the same bytes in the fresh partition
    eng.submit(Request(rid="s0", profile=PROFILES["cnn"], submit_s=eng.now))
    eng._try_admit()
    assert eng.restore_starts == 1
    row2 = eng.active["s0"].partition
    restored = jax.device_get(M.cache_read_row(eng.caches, row2))
    for a, b in zip(jax.tree.leaves(warm_state), jax.tree.leaves(restored)):
        assert np.array_equal(a, b)

    # and one decode step from either state is bit-identical
    prof = PROFILES["cnn"]
    toks = jnp.zeros((1, 1), jnp.int32)
    pos = jnp.full((1,), prof.prompt_tokens, jnp.int32)
    la, _ = M.decode_step(cfg, params, toks, pos,
                          jax.tree.map(jnp.asarray, warm_state))
    lb, _ = M.decode_step(cfg, params, toks, pos,
                          jax.tree.map(jnp.asarray, restored))
    assert np.array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.slow
def test_shrink_under_load_keeps_live_rows_in_range(setup):
    """Regression for the silent row-skew guard: a broker-initiated
    shrink with a live request in flight must leave every bound row
    inside the arena, and the next decode proceeds (no skew, no
    assertion)."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=1e9,
                      seed=0, prewarm=False)
    eng.submit(Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0))
    eng._try_admit()                           # live on row 0
    assert list(eng._row_req) == [0]
    eng._grow_and_sync(2, via_gate=True)       # 2 -> 4 rows
    for i in (1, 2, 3):                        # park warm rows above
        row = eng.arena.admit(f"w{i}")
        eng.warm.setdefault("cnn", []).append((0.0, f"w{i}", row))
    got, ev = eng.reclaim_for_broker(2 * bpp)  # shrink under load
    assert got == 2 * bpp and ev.migrated_bytes == 0
    rows = eng._rows()
    assert rows == 2
    assert all(r < rows for r in eng._row_req)
    eng._decode()                              # decodes, no assertion
    assert eng.active["r0"].position == PROFILES["cnn"].prompt_tokens + 1


@pytest.mark.slow
def test_decode_asserts_on_row_skew(setup):
    """The silent ``if row < rows`` guard is gone: a live request bound
    outside the arena is an invariant violation, surfaced loudly instead
    of decoding a wrong row at position 0."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=1e9,
                      seed=0, prewarm=False)
    eng.submit(Request(rid="r0", profile=PROFILES["cnn"], submit_s=0.0))
    eng._try_admit()
    req = eng.active["r0"]
    del eng._row_req[0]
    eng._row_req[99] = req                     # corrupt: row out of range
    with pytest.raises(AssertionError, match="arena holds only"):
        eng._decode()


@pytest.mark.slow
def test_warm_hit_accounting_route_vs_start(setup):
    """The over-counting fix: the router's warm pick is a route-time
    PREDICTION; keep-alive expiry before the arrival recycles the
    container and the engine cold-starts.  The authoritative counter
    (``warm_starts``) stays 0 while ``warm_routes`` recorded the pick."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp)   # no snapshot pool
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                      seed=0, broker=broker, replica_id="A")
    _run_one(eng, "c0")
    assert eng.warm["cnn"]                     # warm row parked
    router = Router("warm_affinity")
    late = Request(rid="r1", profile=PROFILES["cnn"],
                   submit_s=eng.now + 10.0)
    assert router.route(late, {"A": eng}) == "A"
    assert router.warm_routes == 1             # predicted warm...
    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()                        # ...but the container died
    late.submit_s = eng.now
    eng.run([late])
    assert eng.warm_starts == 0                # outcome: cold start
    assert eng.cold_starts == 2
    assert not any(e.kind == "warm_start" for e in eng.events)


@pytest.mark.slow
def test_recycle_idle_skips_capture_mid_order_drain(setup):
    """Anti-churn rule on the expiry path (mirrors warm-suffix eviction):
    while the engine holds open reclaim orders, keep-alive expiry must
    NOT pay a snapshot capture — the readout would lengthen the very
    drain the requester is waiting on, and the next pressured grant would
    squeeze the snapshot right back."""
    from repro.cluster.host import ReclaimOrder
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                      seed=0, broker=broker, replica_id="A")
    _run_one(eng, "c0")
    assert eng.warm["cnn"]                     # warm row parked
    eng._reclaim_orders.append(ReclaimOrder(
        order_id=99, victim="A", requester="B", units=bpp))
    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()                        # expiry while draining
    assert not any(e.kind == "snapshot" for e in eng.events)
    assert broker.snapshot_units() == 0        # nothing was pooled
    eng._reclaim_orders.clear()                # detach the fake order


@pytest.mark.slow
def test_recycle_idle_captures_once_per_profile(setup):
    """N same-profile containers expiring in one sweep pay ONE readout:
    the pool keys by profile, so same-key replacement would discard all
    but the last capture — the other N-1 device gathers would be pure
    wasted wall on the virtual clock."""
    from repro.serving.engine import ServeEngine
    cfg, params, spec = setup
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                      seed=0, broker=broker, replica_id="A")
    _run_one(eng, "c0")
    _run_one(eng, "c1")                        # both park warm 'cnn' rows
    # c1 adopts c0's row, so make sure TWO distinct rows sit warm
    while len(eng.warm["cnn"]) < 2:
        n = len(eng.warm["cnn"])
        row = eng.arena.admit(f"w{n}")
        eng.warm["cnn"].append((eng.now, f"w{n}", row))
    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()
    snaps = [e for e in eng.events if e.kind == "snapshot"]
    assert len(snaps) == 1                     # one readout, not N
    assert broker.snapshots.inserts == 1
    assert not eng.warm["cnn"]


# ----------------------------------- (d) tenant-fair squeeze protection


def test_squeeze_respects_other_tenants_sub_budget():
    """The fairness rule: one tenant's grant pressure may skim another
    tenant's snapshot SURPLUS (usage above its sub-budget) but never
    drop the owner below its sub-budget — the protected entries are
    skipped and the deficit falls through to reclaim orders instead."""
    from collections import deque
    orders = deque()
    broker = HostMemoryBroker(10, async_reclaim=True,
                              snapshot_pool_units=5,
                              tenants={"a": 5, "b": 5},
                              clock=_fake_clock())
    broker.register("vA", 3, load=lambda: 0, tenant="a",
                    order_sink=orders.append, mode="model")
    broker.register("vB", 2, load=lambda: 9, tenant="b", mode="model")
    for k in ("a1", "a2", "a3"):
        assert broker.snapshot_put(k, units=1, nbytes=64, replica_id="vA")
    assert broker.ledger.tenant_usage("a") == 6     # 3 granted + 3 pooled
    broker.check_invariants()

    g = broker.request_grant("vB", 6)               # free 2 + deficit 4
    # exactly ONE of a's entries was squeeze-eligible (usage 6 -> 5 ==
    # sub-budget); the other two are protected, the rest went to orders
    assert [r.tenant for r in broker.squeeze_log] == ["a"]
    assert broker.snapshots.units == 2
    assert broker.ledger.tenant_usage("a") == broker.ledger.sub_budgets["a"]
    assert g.granted == 3                           # free 2 + squeezed 1
    assert orders and sum(o.units for o in orders) == 3
    broker.check_invariants()
    broker.cancel_order(orders[0].order_id)

    # the owner's OWN pressure drops its own entries freely
    g2 = broker.request_grant("vA", 2)
    assert g2.granted == 2
    assert broker.snapshots.units == 0
    assert [r.tenant for r in broker.squeeze_log] == ["a", "a", "a"]
    broker.check_invariants()


def test_snapshot_put_refuses_replacing_protected_entry():
    """Same-key replacement is still a drop of the predecessor: tenant b
    cannot overwrite tenant a's protected entry even when free units
    would cover the new charge — room and put agree (both are the one
    ``_evict_plan``), and nothing is mutated on refusal."""
    broker = HostMemoryBroker(8, snapshot_pool_units=3,
                              tenants={"a": 4, "b": 4},
                              clock=_fake_clock())
    assert broker.snapshot_put("k", units=1, nbytes=64, tenant="a")
    assert not broker.snapshot_room("k", 1, tenant="b")
    assert not broker.snapshot_put("k", units=1, nbytes=64, tenant="b")
    assert broker.snapshots.peek("k").tenant == "a"  # untouched
    # a fresh key needs no drop, so b inserts fine from the free pool
    assert broker.snapshot_room("k2", 1, tenant="b")
    assert broker.snapshot_put("k2", units=1, nbytes=64, tenant="b")
    assert broker.ledger.tenant_snapshot("a") == 1
    assert broker.ledger.tenant_snapshot("b") == 1
    broker.check_invariants()


def test_pool_evict_lru_eligible_skips_without_reordering():
    """The predicate path: protected entries are skipped in place — the
    survivor order is unchanged — and ``evict(key)`` drops a specific
    entry counted as an eviction (unlike same-key ``drop``)."""
    from repro.cluster.snapshots import Snapshot
    pool = SnapshotPool()
    for i, k in enumerate(("old", "mid", "new")):
        pool.insert(Snapshot(key=k, units=1, tokens=0, nbytes=0,
                             payload=None, replica_id="r",
                             created_at=float(i), last_used=float(i)))
    got = pool.evict_lru(eligible=lambda s: s.key != "old")
    assert got is not None and got.key == "mid"      # LRU among eligible
    assert pool.keys() == ["old", "new"]             # no reorder
    assert pool.evict_lru(eligible=lambda s: False) is None
    assert pool.keys() == ["old", "new"]
    before = pool.evictions
    got = pool.evict("new")
    assert got is not None and got.key == "new"
    assert pool.evictions == before + 1
    assert pool.evict("gone") is None
    assert pool.evictions == before + 1
