"""The scenario bank's regression surface: pinned row schema, golden
bit-identical rows, the SLO-tier acceptance bar, hedged-dispatch
coverage, and per-tenant-stream trace determinism.

The bank (``repro.cluster.scenarios``) exists so a fairness or tail
regression between PRs is a loud diff; these tests pin the contract:

  (a) ``ROW_SCHEMA`` is frozen — a key added, removed, or reordered is
      a deliberate schema bump, surfaced here first;
  (b) every scenario is a pure function of (name, seed): same-seed
      reruns are bit-identical, the committed ``benchmarks/BENCH_6.json``
      baseline is exactly reproducible, a different seed diverges;
  (c) the slo family's acceptance bar: under ``slo_tiered`` the tight
      tier's TTFT p99 beats the batch tier's (batch routes AND starts
      cold; tight spends the warm/snapshot capacity batch leaves alone);
  (d) the hedge family: a straggler primary fires the backup on the
      OTHER host, and every request still runs on exactly one replica —
      exactly one result charged;
  (e) ``tracegen`` per-stream seeding: named streams are independent,
      process-stable child rngs; ``stream=None`` reproduces the legacy
      single-seed draws bit-for-bit.
"""
import json
import os

import numpy as np
import pytest

from repro.cluster.scenarios import (ROW_SCHEMA, SCENARIOS, SMOKE,
                                     TIME_FIELDS, HedgedRoutePolicy,
                                     _build, run_bank, run_scenario)
from repro.serving.request import PROFILES, Request, State
from repro.serving.tracegen import (assign_profiles, bursty_trace,
                                    diurnal_trace, stream_seed)

BASELINE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_6.json")
MESH_BASELINE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                             "BENCH_7.json")
AUTOSCALE_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                                  "benchmarks", "BENCH_8.json")
DEDUP_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                              "benchmarks", "BENCH_9.json")


def _committed_baseline() -> dict:
    """The full committed surface: BENCH_6 (single-device bank) merged
    with BENCH_7 (the mesh family), BENCH_8 (the autoscale family), and
    BENCH_9 (the dedup family) — each scenario lives in exactly one
    file."""
    merged: dict = {}
    for path in (BASELINE, MESH_BASELINE, AUTOSCALE_BASELINE,
                 DEDUP_BASELINE):
        with open(path) as f:
            part = json.load(f)
        assert not set(merged) & set(part)
        merged.update(part)
    return merged


# ------------------------------------------------------- (a) schema pin


def test_row_schema_is_pinned():
    """The frozen key set, in order: changing it is a schema bump that
    must touch this literal AND the committed baseline."""
    assert ROW_SCHEMA == (
        "scenario", "family", "seed", "policy", "hosts", "replicas",
        "tenants", "requests", "completed", "killed",
        "warm_ttft_ms", "restore_ttft_ms", "cold_ttft_ms",
        "ttft_p99_ms_by_tier", "stall_p99_ms",
        "warm_starts", "restore_starts", "remote_restore_starts",
        "cold_starts", "squeezes_by_tenant", "reclaim_orders",
        "order_units", "snapshot_migrations", "host_boots",
        "host_retires", "hedges", "routes",
        "host_seconds", "free_units_end", "device_units_end",
        "unique_snapshot_units", "dedup_ratio", "migrated_snapshot_bytes",
    )
    assert set(TIME_FIELDS) < set(ROW_SCHEMA)
    assert set(SMOKE) < set(SCENARIOS)
    # one smoke scenario per family, every family covered
    assert sorted({SCENARIOS[n][0] for n in SMOKE}) \
        == sorted({fam for fam, _ in SCENARIOS.values()})


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_every_row_carries_the_schema(name):
    row = run_scenario(name, seed=0)
    assert tuple(row) == ROW_SCHEMA
    assert row["scenario"] == name
    assert row["completed"] + row["killed"] == row["requests"] > 0
    assert sum(row["routes"].values()) == row["requests"]


# -------------------------------------------------- (b) golden determinism


def test_same_seed_rerun_is_bit_identical():
    a = json.dumps(run_scenario("fairness_smoke", seed=0), sort_keys=True)
    b = json.dumps(run_scenario("fairness_smoke", seed=0), sort_keys=True)
    assert a == b
    c = json.dumps(run_scenario("fairness_smoke", seed=1), sort_keys=True)
    assert a != c                       # the seed actually reaches the rng


def test_bank_reproduces_the_committed_baseline_exactly():
    """The committed baselines are not a tolerance band here: the bank
    is virtual-clocked end to end, so the committed rows are exactly
    reproducible.  A diff means behavior changed — refresh deliberately
    with ``benchmarks/run.py --scenarios --update-baseline``."""
    baseline = _committed_baseline()
    rows = json.loads(json.dumps(run_bank(seed=0), sort_keys=True))
    assert sorted(rows) == sorted(baseline)
    for name in sorted(baseline):
        assert rows[name] == baseline[name], f"row drifted: {name}"


def test_golden_diurnal_smoke_fields():
    """Inline golden pin for one smoke row (independent of the baseline
    file): the discrete fields a seed-0 run must land on."""
    row = run_scenario("diurnal_smoke", seed=0)
    assert row["family"] == "diurnal"
    assert row["tenants"] == ["acme", "beta"]
    assert (row["hosts"], row["replicas"]) == (1, 2)
    assert row["requests"] == 77
    assert row["completed"] == 77 and row["killed"] == 0
    # both tenants' expired-warm snapshots got squeezed under pressure,
    # and the async order plane re-grew the trough tenant's rows
    assert row["squeezes_by_tenant"] == {"acme": 2, "beta": 3}
    assert row["reclaim_orders"] == 52
    assert row["warm_starts"] + row["restore_starts"] \
        + row["remote_restore_starts"] + row["cold_starts"] == 77


# ------------------------------------------------- (c) slo acceptance bar


def test_slo_tiered_tight_p99_beats_batch_p99():
    row = run_scenario("slo_tiered", seed=0)
    assert row["policy"] == "slo_tiered"
    tiers = row["ttft_p99_ms_by_tier"]
    assert set(tiers) == {"tight", "batch"}
    assert tiers["tight"] < tiers["batch"], tiers
    # the tight tier actually used the cached paths; batch stayed cold
    assert row["warm_starts"] + row["restore_starts"] > 0
    assert row["cold_starts"] > 0


# --------------------------------------------------- (d) hedged dispatch


def test_hedged_backup_fires_on_other_host_one_result_charged():
    """A straggler primary (every cost x50) misses the deadline, so the
    hedge fires the backup on the OTHER host; each request still runs on
    exactly one replica, so exactly one result is charged per rid."""
    hosts = {"hA": [("hA/r0", 3, None, 50.0, 1)],     # the straggler
             "hB": [("hB/r0", 3, None, 1.0, 1)]}
    policy = HedgedRoutePolicy(deadline_s=0.02)
    sim, sched = _build(hosts, budget=8, pool_units=2, tenants=None,
                        seed=0, route_fn=policy)
    reqs = [Request(rid=f"r{i}", profile=PROFILES["cnn"],
                    submit_s=0.002 * i) for i in range(12)]
    m = sim.run(list(reqs))
    assert policy.hedges > 0
    for rid, chosen in policy.chosen_log:
        if len(chosen) > 1:             # the hedge crossed hosts
            assert chosen[0] == "hA/r0" and chosen[-1] == "hB/r0"
    # exactly one result per request, no duplicates across replicas
    done = [r for e in sim.engines.values() for r in e.done]
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    assert m["completed"] == len(reqs) and m["killed"] == 0
    assert all(r.state is State.DONE for r in done)
    sched.check_invariants()


def test_hedged_fleet_row_counts_hedges():
    row = run_scenario("hedged_fleet", seed=0)
    assert row["hedges"] > 0
    assert row["hosts"] == 2


# ------------------------------------------- (e) tracegen stream seeding


def test_stream_seeds_are_independent_and_stable():
    """Named streams derive from (seed, crc32(name)) only: stable across
    calls, distinct across names, distinct from the legacy path."""
    a1 = bursty_trace(1.0, 50.0, seed=0, stream="acme")
    a2 = bursty_trace(1.0, 50.0, seed=0, stream="acme")
    b = bursty_trace(1.0, 50.0, seed=0, stream="beta")
    legacy = bursty_trace(1.0, 50.0, seed=0)
    assert a1 == a2
    assert a1 != b and a1 != legacy
    assert list(stream_seed(0, "acme").entropy) \
        == list(stream_seed(0, "acme").entropy)
    assert list(stream_seed(0, "acme").entropy) \
        != list(stream_seed(0, "beta").entropy)


def test_assign_profiles_stream_rng_is_per_stream():
    """The fix under test: two tenants' profile picks come from
    independent child rngs — one tenant's picks are a function of its
    own stream name, not of whatever else the scenario drew — while
    ``stream=None`` reproduces the legacy ``seed + 1`` draws exactly."""
    profs = {n: PROFILES[n] for n in ("cnn", "bert")}
    arr = [0.1 * i for i in range(40)]
    sa = [p.name for _, p in assign_profiles(arr, profs, seed=0,
                                             stream="a")]
    sb = [p.name for _, p in assign_profiles(arr, profs, seed=0,
                                             stream="b")]
    assert sa == [p.name for _, p in assign_profiles(arr, profs, seed=0,
                                                     stream="a")]
    assert sa != sb                     # independent streams diverge
    # legacy path: bit-identical to the pre-stream implementation
    rng = np.random.default_rng(0 + 1)
    names = list(profs)
    w = np.array([profs[n].weight for n in names], float)
    w /= w.sum()
    picks = rng.choice(len(names), size=len(arr), p=w)
    legacy = [p.name for _, p in assign_profiles(arr, profs, seed=0)]
    assert legacy == [names[i] for i in picks]


# ------------------------------------------ (f) twin vs real-engine parity


class _StepClock:
    """Deterministic stand-in for ``time``: each ``perf_counter`` call
    advances a fixed step, so the real engine's wall-measured virtual
    costs are reproducible."""

    def __init__(self, step=1e-4):
        self._t = 0.0
        self._step = step

    def perf_counter(self):
        self._t += self._step
        return self._t


@pytest.mark.slow
def test_model_replica_twin_matches_real_engine(monkeypatch):
    """The bank's credibility anchor: on a workload whose admission path
    is unambiguous (widely spaced arrivals, keep-alive zero, no snapshot
    pool — every start MUST be cold), the ``ModelReplica`` twin's row
    counts equal a real ``ServeEngine`` fleet's exactly, and the twin's
    fixed-virtual-cost cold TTFT lands within a wide tolerance band of
    the deterministically clocked engine's."""
    import jax

    import repro.core.elastic as elastic_mod
    import repro.core.hotmem as hotmem_mod
    import repro.core.vanilla as vanilla_mod
    import repro.serving.engine as engine_mod
    from repro.cluster import ClusterSim, HostMemoryBroker, Router
    from repro.cluster.scenarios import ModelReplica, _row
    from repro.configs.base import get_config, reduced
    from repro.core.arena import ArenaSpec
    from repro.models import model as M
    from repro.serving.engine import ServeEngine

    def mk_reqs():
        return [Request(rid=f"p{i}", profile=PROFILES["cnn"],
                        submit_s=0.5 * i) for i in range(5)]

    # --- twin: keep-alive zero so warm reuse is impossible in BOTH worlds
    monkeypatch.setattr(ModelReplica, "KEEPALIVE_S", 0.0)
    sim, sched = _build({"h0": [("h0/r0", 2, None, 1.0, 1)]}, budget=8,
                        pool_units=None, tenants=None, seed=0)
    twin_reqs = mk_reqs()
    sim.run(list(twin_reqs))
    row = _row("twin_parity", "scaledown", 0, "drain_weighted", sim,
               sched, twin_reqs)

    # --- real fleet: same workload (fresh Request objects — they mutate)
    clock = _StepClock()
    for mod in (engine_mod, elastic_mod, hotmem_mod, vanilla_mod):
        monkeypatch.setattr(mod, "time", clock)
    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128,
                                n_partitions=8, block_tokens=32)
    broker = HostMemoryBroker(
        budget_units=8 * spec.blocks_per_partition, async_reclaim=True)
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=0.0,
                      seed=0, broker=broker, replica_id="h0/r0",
                      prewarm=False)
    real_reqs = mk_reqs()
    m = ClusterSim({"h0/r0": eng}, Router("least_loaded"), broker).run(
        list(real_reqs), max_virtual_s=2000)
    broker.check_invariants()

    # counts: exactly equal, start path by start path
    assert row["requests"] == len(real_reqs) == 5
    assert (row["completed"], row["killed"]) \
        == (m["completed"], m["killed"]) == (5, 0)
    assert row["cold_starts"] == eng.cold_starts == 5
    assert row["warm_starts"] == eng.warm_starts == 0
    assert row["restore_starts"] == eng.restore_starts == 0
    assert row["remote_restore_starts"] == eng.remote_restore_starts == 0

    # times: modeled vs clocked cold TTFT within a wide (but unit-error-
    # catching) band — the twin is a cost MODEL, not a profile
    real_cold_ms = sorted(r.first_token_s - r.submit_s
                          for r in eng.done)[2] * 1e3
    assert row["cold_ttft_ms"] is not None and row["cold_ttft_ms"] > 0
    assert real_cold_ms > 0
    assert row["cold_ttft_ms"] / 100.0 <= real_cold_ms \
        <= row["cold_ttft_ms"] * 100.0, (row["cold_ttft_ms"], real_cold_ms)


def test_diurnal_trace_phase_shifts_the_peak():
    """Opposite-phase tenants peak in opposite halves of the period —
    the diurnal-mix scenario's premise."""
    dur = 1.0
    day = diurnal_trace(dur, 200.0, period_s=dur, depth=0.8, phase=0.0,
                        seed=0, stream="day")
    night = diurnal_trace(dur, 200.0, period_s=dur, depth=0.8,
                          phase=np.pi, seed=0, stream="night")
    assert all(0.0 <= t < dur for t in day + night)
    assert day == sorted(day) and night == sorted(night)
    half = dur / 2
    assert sum(t < half for t in day) > sum(t >= half for t in day)
    assert sum(t < half for t in night) < sum(t >= half for t in night)
