"""Quickstart: build a model from the assigned pool, train a few steps,
then serve it with the HotMem partitioned arena.

  PYTHONPATH=src python examples/quickstart.py [--arch qwen2-7b]
"""
import argparse
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs, reduced
from repro.models import model as M
from repro.training.optimizer import init_opt_state
from repro.training.train_step import make_batch_labels, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=list_archs())
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))      # tiny same-family config (CPU)
    print(f"arch={cfg.name} family={cfg.family} "
          f"full-size params={get_config(args.arch).param_count()/1e9:.2f}B")

    # --- train a few steps -------------------------------------------------
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              cfg.vocab_size)
    batch = make_batch_labels(toks)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((4, cfg.encoder_src_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros(
            (4, cfg.vision_stub_tokens, cfg.d_model))
    for i in range(5):
        state, m = step(state, batch)
        print(f"train step {i}: loss={float(m['loss']):.4f}")

    # --- serve: prefill + decode through the partition arena ---------------
    caches = M.init_caches(cfg, batch=2, cache_len=64)
    prompt = toks[:2, :16]
    pb = {k: (v[:2] if hasattr(v, "shape") else v) for k, v in batch.items()
          if k != "labels"}
    pb["tokens"] = prompt
    logits, caches = M.prefill(cfg, state["params"], pb, caches)
    out = [prompt]
    pos = jnp.full((2,), 16, jnp.int32)
    for i in range(8):
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(nxt)
        logits, caches = M.decode_step(cfg, state["params"], nxt, pos + i,
                                       caches)
    gen = jnp.concatenate(out, axis=1)
    print(f"generated shapes: {gen.shape} (prompt 16 + 8 new tokens)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
