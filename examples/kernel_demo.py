"""Kernel-level view of the paper's insight: decode attention over
contiguous HotMem partitions vs the vanilla paged layout, plus the
kv_compact migration pass that HotMem eliminates.

  PYTHONPATH=src python examples/kernel_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def main() -> None:
    rng = np.random.default_rng(0)
    p, t, hkv, g, dh, bt = 4, 256, 2, 4, 64, 64
    q = jnp.asarray(rng.normal(size=(p, hkv, g, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), jnp.float32)
    pos = jnp.asarray([255, 100, 30, 200], jnp.int32)

    out = ops.partition_attention(q, k, v, pos)      # Pallas (interpret)
    want = ref.partition_attention(q, k, v, pos)     # jnp oracle
    print("partition_attention max err vs oracle:",
          float(jnp.max(jnp.abs(out - want))))

    # same KV scattered across a paged pool
    nb = p * (t // bt)
    perm = rng.permutation(nb)
    inv = np.argsort(perm)
    kp = k.reshape(nb, bt, hkv, dh)[perm]
    vp = v.reshape(nb, bt, hkv, dh)[perm]
    tables = jnp.asarray(inv.reshape(p, t // bt), jnp.int32)
    paged = ops.paged_attention(q, kp, vp, tables, pos)
    print("paged_attention max err vs partition:",
          float(jnp.max(jnp.abs(paged - out))))

    # the migration pass vanilla pays before shrinking (HotMem: never)
    src = jnp.asarray([nb - 1, nb - 2], jnp.int32)
    dst = jnp.asarray([0, 1], jnp.int32)
    compacted = ops.kv_compact(kp, src, dst)
    assert bool(jnp.array_equal(compacted[0], kp[nb - 1]))
    print("kv_compact moved 2 blocks (the copies HotMem never issues)")
    print("kernel demo OK")


if __name__ == "__main__":
    main()
