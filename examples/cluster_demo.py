"""Multi-replica host demo: one memory budget, two VM replicas, a broker.

Replica B handles early steady load then idles (kept-alive containers);
replica A's later burst outgrows the host's free pool, so the broker
reclaims B's memory — sub-second and zero-copy under HotMem, migration
copies under the vanilla paged baseline.

Each mode runs twice: with the synchronous broker (A's plug request
serializes behind B's unplug — the ``stall_p99`` column is what A waits)
and with the async reclaim pipeline (B receives a ``ReclaimOrder`` and
drains it between its own ticks while A keeps decoding; A's stall is 0
and the grant completes incrementally).

``--policy`` selects the router: the default ``pinned`` route reproduces
the classic steal scenario; any ``repro.cluster.router`` policy name
spreads the shared trace instead.  ``snapshot_affinity`` also enables the
host snapshot pool: expiring warm containers are copied out and later
invocations restore from the pool instead of prefilling (the ``warm``/
``restore`` columns count engine-side start paths; ``squeezed`` counts
snapshot units the broker dropped — metadata-only — to cover grants).

  PYTHONPATH=src python examples/cluster_demo.py
  PYTHONPATH=src python examples/cluster_demo.py \
      --policy snapshot_affinity --modes hotmem
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_platform_name", "cpu")

import numpy as np

from repro.cluster import ClusterSim, HostMemoryBroker, Router
from repro.cluster.router import POLICIES
from repro.configs.base import get_config, reduced
from repro.core.arena import ArenaSpec
from repro.models import model as M
from repro.serving.engine import ServeEngine
from repro.serving.request import PROFILES, Request
from repro.serving.tracegen import assign_profiles, bursty_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="pinned",
                    choices=("pinned",) + POLICIES,
                    help="router policy (pinned = quiet load on B, "
                         "burst on A — the classic steal scenario)")
    ap.add_argument("--modes", default="hotmem,vanilla",
                    help="comma-separated engine modes to run")
    args = ap.parse_args()

    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    bpp = spec.blocks_per_partition
    # snapshot_affinity is the policy that exploits the host snapshot
    # pool, so only it pays for one (4 partitions' worth, LRU-bounded)
    pool_units = 4 * bpp if args.policy == "snapshot_affinity" else None

    print(f"policy={args.policy}")
    print(f"{'mode':10s} {'broker':6s} {'completed':>9s} {'steals':>6s} "
          f"{'stall_p99_ms':>12s} {'steal_ms':>9s} {'migratedKiB':>11s} "
          f"{'lat_p99_s':>9s} {'warm':>5s} {'restore':>7s} {'squeezed':>8s}")
    for mode in args.modes.split(","):
        for async_mode in (False, True):
            # host budget: 10 partitions' worth — less than 2 full arenas,
            # so A's burst cannot grow without shrinking B (or squeezing
            # the snapshot pool first, when one exists)
            broker = HostMemoryBroker(budget_units=10 * bpp,
                                      async_reclaim=async_mode,
                                      snapshot_pool_units=pool_units)
            engines = {rid: ServeEngine(cfg, params, spec, mode=mode,
                                        keep_alive=3.0, seed=i,
                                        broker=broker, replica_id=rid)
                       for i, rid in enumerate(("A", "B"))}
            quiet = bursty_trace(6.0, 0.9, burst_x=1.0, burst_len=0.0,
                                 seed=2)
            burst = [4.0 + t for t in bursty_trace(
                4.0, 3.0, burst_x=3.0, burst_at=(0.0,), burst_len=2.0,
                seed=3)]
            reqs = [Request(rid=f"b{i}", profile=p, submit_s=t)
                    for i, (t, p) in enumerate(
                        assign_profiles(quiet, PROFILES, 2))]
            reqs += [Request(rid=f"a{i}", profile=p, submit_s=t)
                     for i, (t, p) in enumerate(
                         assign_profiles(burst, PROFILES, 3))]
            if args.policy == "snapshot_affinity":
                # a late tail, arriving after every warm container has
                # expired (and been captured): these invocations restore
                # from the pool instead of prefilling
                reqs += [Request(rid=f"t{i}", profile=PROFILES[p],
                                 submit_s=12.0 + 0.5 * i)
                         for i, p in enumerate(
                             ("cnn", "bert", "bfs", "html"))]
            if args.policy == "pinned":
                router = Router(route_fn=lambda r, e:
                                "B" if r.rid.startswith("b") else "A")
            else:
                router = Router(args.policy, broker=broker)
            m = ClusterSim(engines, router, broker).run(reqs,
                                                        max_virtual_s=2000)
            rep = m["broker"]["by_mode"].get(mode, {})
            stalls = broker.request_stalls or [0.0]
            print(f"{mode:10s} {'async' if async_mode else 'sync':6s} "
                  f"{m['completed']:9d} "
                  f"{rep.get('steals', 0):6d} "
                  f"{float(np.percentile(stalls, 99)) * 1e3:12.2f} "
                  f"{rep.get('wall_seconds', 0.0) * 1e3:9.2f} "
                  f"{rep.get('migrated_bytes', 0) / 1024:11.1f} "
                  f"{(m['latency_p99'] or 0):9.2f} "
                  f"{m['warm_hits']:5d} {m['restore_starts']:7d} "
                  f"{m['broker']['squeezed_units']:8d}")
    print("\nThe broker reclaims the idle replica's memory for the loaded"
          "\none; HotMem makes that host-level steal zero-copy, the paged"
          "\nbaseline pays real migration bytes for the same elasticity —"
          "\nand the async reclaim pipeline removes the requester-visible"
          "\nstall entirely (stall_p99 -> 0): victims drain ReclaimOrders"
          "\nbetween their own ticks while the requester keeps decoding."
          "\nWith --policy snapshot_affinity the host also pools expired"
          "\nwarm containers' prefix KV: later invocations restore from"
          "\nthe pool instead of prefilling, and under pressure the"
          "\nbroker squeezes those snapshot units first (metadata-only)"
          "\nbefore ordering any VM to shrink.")


if __name__ == "__main__":
    main()
