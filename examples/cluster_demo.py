"""Multi-replica host demo: one memory budget per host, VM replicas, a
broker per host — and, with ``--hosts N``, a fleet of hosts.

Replica B handles early steady load then idles (kept-alive containers);
replica A's later burst outgrows the host's free pool, so the broker
reclaims B's memory — sub-second and zero-copy under HotMem, migration
copies under the vanilla paged baseline.

Each mode runs twice: with the synchronous broker (A's plug request
serializes behind B's unplug — the ``stall_p99`` column is what A waits)
and with the async reclaim pipeline (B receives a ``ReclaimOrder`` and
drains it between its own ticks while A keeps decoding; A's stall is 0
and the grant completes incrementally).

``--policy`` selects the router: the default ``pinned`` route reproduces
the classic steal scenario; any ``repro.cluster.router`` policy name
spreads the shared trace instead.  ``snapshot_affinity`` and
``drain_weighted`` also enable the host snapshot pool: expiring warm
containers are copied out and later invocations restore from the pool
instead of prefilling (the ``warm``/``restore`` columns count
engine-side start paths; ``squeezed`` counts snapshot units the broker
dropped — metadata-only — to cover grants).

``--hosts N`` splits the replicas across N hosts (one broker + budget +
snapshot pool each, placed via ``FleetScheduler`` spread placement) and
runs them under ``FleetSim``.  Budgets are then per-host uncontended, so
steals vanish — what appears instead is cross-host warm-state migration:
B's expired containers are captured on B's host, and the late tail
pinned to A pulls those snapshots over (``mig`` column; modeled
inter-host copy over real payload bytes), so A restores remotely
(``remote`` column) instead of cold-prefilling.

``--devices N`` gives every host an N-device mesh: each replica's KV
stripes one shard per device, the broker arbitrates per-device budgets
(reclaim orders drain one unit per shard in lockstep), and the table
grows a per-device occupancy line per host (free/granted/snapshot units
on every device — balanced throughout, which is the point).  Vanilla
mode plugs single blocks, which cannot stripe, so ``--devices > 1``
requires ``--modes hotmem``.

``--scenario NAME`` runs one entry of the multi-tenant scenario bank
(``repro.cluster.scenarios``) instead of the engine demo and prints its
report row — the same deterministic rows ``benchmarks/run.py
--scenarios`` gates against ``BENCH_6.json``/``BENCH_7.json``/
``BENCH_8.json``.

``--autoscale`` runs the host-lifecycle scenarios (the ``autoscale``
family): a burst boots hosts through the low-water slack mark, the
quiet tail retires the emptiest host, and retirement DRAINS the host's
snapshot pool to peers over the contended interconnect instead of
discarding it.  Prints a per-scenario lifecycle summary (boots,
retires, migrations, TTFT).

``--dedup`` demos the content-addressed snapshot store on real engines:
several functions with byte-identical prompts are captured as page
manifests (``--page-size`` bytes per page, also honored by the main
demo), so the pool charges each unique page ONCE (unique vs referenced
units) and a second replica's restores find the shared pages already
mapped — copy-on-write, reported as the shared-page restore ratio.

  PYTHONPATH=src python examples/cluster_demo.py
  PYTHONPATH=src python examples/cluster_demo.py \
      --policy snapshot_affinity --modes hotmem
  PYTHONPATH=src python examples/cluster_demo.py --hosts 2 --modes hotmem
  PYTHONPATH=src python examples/cluster_demo.py --devices 2 --modes hotmem
  PYTHONPATH=src python examples/cluster_demo.py --scenario slo_tiered
  PYTHONPATH=src python examples/cluster_demo.py --autoscale
  PYTHONPATH=src python examples/cluster_demo.py --dedup --page-size 4096
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_platform_name", "cpu")

import numpy as np

from repro.cluster import (ClusterSim, DeviceTopology, FleetScheduler,
                           FleetSim, HostMemoryBroker, Router)
from repro.cluster.router import POLICIES
from repro.configs.base import get_config, reduced
from repro.core.arena import ArenaSpec
from repro.models import model as M
from repro.serving.engine import ServeEngine
from repro.serving.request import PROFILES, Request
from repro.serving.tracegen import assign_profiles, bursty_trace


def _reqs(pooled: bool):
    quiet = bursty_trace(6.0, 0.9, burst_x=1.0, burst_len=0.0, seed=2)
    burst = [4.0 + t for t in bursty_trace(4.0, 3.0, burst_x=3.0,
                                           burst_at=(0.0,), burst_len=2.0,
                                           seed=3)]
    reqs = [Request(rid=f"b{i}", profile=p, submit_s=t)
            for i, (t, p) in enumerate(assign_profiles(quiet, PROFILES, 2))]
    reqs += [Request(rid=f"a{i}", profile=p, submit_s=t)
             for i, (t, p) in enumerate(assign_profiles(burst, PROFILES, 3))]
    if pooled:
        # a late tail, arriving after every warm container has expired
        # (and been captured): these invocations restore from the pool —
        # cross-host under --hosts > 1 — instead of prefilling
        reqs += [Request(rid=f"t{i}", profile=PROFILES[p],
                         submit_s=12.0 + 0.5 * i)
                 for i, p in enumerate(("cnn", "bert", "bfs", "html"))]
    return reqs


def _dedup_demo(args) -> None:
    """Content-addressed pool on real engines: N functions whose cold
    prompts are byte-identical produce byte-identical prefix KV, so
    their page manifests share every digest — the pool charges ONE copy
    (unique vs referenced units) and a second replica's restores find
    the shared pages already mapped (copy-on-write, no re-copy)."""
    import dataclasses

    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    bpp = spec.blocks_per_partition
    page_bytes = args.page_size or 4096
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=6 * bpp)
    cap = ServeEngine(cfg, params, spec, keep_alive=0.4, seed=0,
                      broker=broker, replica_id="A",
                      snapshot_page_bytes=page_bytes)
    rst = ServeEngine(cfg, params, spec, keep_alive=0.4, seed=1,
                      broker=broker, replica_id="B",
                      snapshot_page_bytes=page_bytes)
    # the engine's cold prompt is np.full(prompt_tokens, hash(name) % 97
    # + 1): same residue + same token count = byte-identical prompt =
    # byte-identical prefix KV.  hash() is salted per process, so SEARCH
    # for colliding names instead of hardcoding them.
    base = PROFILES["cnn"]
    names, i = ["dup0"], 1
    while len(names) < 4 and i < 100_000:
        if hash(f"dup{i}") % 97 == hash("dup0") % 97:
            names.append(f"dup{i}")
        i += 1
    assert len(names) == 4
    profs = {n: dataclasses.replace(base, name=n) for n in names}

    # phase 1: replica A runs every function cold; run() drains until the
    # warm containers age out, capturing each as a page manifest
    cap.run([Request(rid=f"c{j}", profile=profs[n], submit_s=0.2 * j)
             for j, n in enumerate(names)], max_virtual_s=200)
    assert all(broker.snapshot_restorable(n) for n in names), \
        "captures did not land in the pool"
    broker.check_invariants()
    pool = broker.snapshots
    ref, uniq = pool.referenced_units, broker.snapshot_units()

    # phase 2: replica B (never ran any of them) restores all four; after
    # the first manifest materializes, the rest map already-shared pages
    rst.run([Request(rid=f"r{j}", profile=profs[n], submit_s=0.0)
             for j, n in enumerate(names)], max_virtual_s=200)
    broker.check_invariants()
    restores = [e for e in rst.events if e.kind == "restore"]
    total = sum(e.detail["pages_total"] for e in restores)
    shared = sum(e.detail["pages_shared"] for e in restores)

    print(f"page_size={page_bytes}B  functions={len(names)} "
          f"(byte-identical {base.prompt_tokens}-token prompts)")
    print(f"{'referenced_units':>16s} {'unique_units':>12s} "
          f"{'dedup_ratio':>11s} {'restores':>8s} {'pages':>6s} "
          f"{'shared':>6s} {'cow_ratio':>9s}")
    print(f"{ref:16d} {uniq:12d} "
          f"{(uniq / ref if ref else 1.0):11.3f} "
          f"{len(restores):8d} {total:6d} {shared:6d} "
          f"{(shared / total if total else 0.0):9.3f}")
    print("\nEvery function's prefix KV is byte-identical, so the"
          "\ncontent-addressed pool stores and charges each page once:"
          "\nunique_units is what the ledger's snapshot account holds,"
          "\nreferenced_units what the manifests add up to.  Replica B"
          "\nnever ran these functions; its first restore materializes"
          "\nthe pages, and the remaining restores find them already"
          "\nmapped (shared/pages) — they remap copy-on-write instead"
          "\nof paying the copy wall again (cow_ratio).")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="pinned",
                    choices=("pinned",) + POLICIES,
                    help="router policy (pinned = quiet load on B, "
                         "burst on A — the classic steal scenario)")
    ap.add_argument("--modes", default="hotmem,vanilla",
                    help="comma-separated engine modes to run")
    ap.add_argument("--hosts", type=int, default=1,
                    help="number of hosts; > 1 places replicas across "
                         "per-host brokers and enables cross-host "
                         "snapshot migration (FleetSim)")
    ap.add_argument("--devices", type=int, default=1,
                    help="devices per host: > 1 stripes every replica's "
                         "KV one shard per device behind per-device "
                         "broker budgets and prints per-device occupancy "
                         "(hotmem only — vanilla cannot stripe)")
    ap.add_argument("--scenario", default=None,
                    help="run one scenario-bank entry (see "
                         "repro.cluster.scenarios.SCENARIOS) and print "
                         "its report row instead of the engine demo")
    ap.add_argument("--autoscale", action="store_true",
                    help="run the host-lifecycle (autoscale family) "
                         "scenarios and print a lifecycle summary "
                         "instead of the engine demo")
    ap.add_argument("--dedup", action="store_true",
                    help="demo the content-addressed snapshot store: "
                         "capture functions with identical prompts as "
                         "page manifests and print unique vs referenced "
                         "units plus the shared-page restore ratio")
    ap.add_argument("--page-size", type=int, default=None,
                    help="content-addressed snapshot page size in bytes "
                         "(enables paged capture on the demo engines; "
                         "--dedup defaults to 4096)")
    ap.add_argument("--seed", type=int, default=0,
                    help="scenario seed (--scenario/--autoscale only)")
    args = ap.parse_args()
    assert args.hosts >= 1
    assert args.devices >= 1
    assert args.page_size is None or args.page_size > 0
    assert args.devices == 1 or "vanilla" not in args.modes.split(","), \
        "--devices > 1 requires --modes without vanilla (single-block " \
        "plugs cannot stripe over a mesh)"

    if args.dedup:
        _dedup_demo(args)
        return

    if args.autoscale:
        from repro.cluster.scenarios import SCENARIOS, run_scenario
        names = sorted(n for n, (fam, _) in SCENARIOS.items()
                       if fam == "autoscale")
        print(f"{'scenario':16s} {'reqs':>5s} {'hosts':>5s} {'boots':>5s} "
              f"{'retires':>7s} {'mig':>4s} {'warm':>5s} {'restore':>7s} "
              f"{'cold':>5s} {'host_s':>8s} {'p99_ms':>8s}")
        for name in names:
            row = run_scenario(name, seed=args.seed)
            p99 = max(v for v in row["ttft_p99_ms_by_tier"].values())
            print(f"{name:16s} {row['requests']:5d} {row['hosts']:5d} "
                  f"{row['host_boots']:5d} {row['host_retires']:7d} "
                  f"{row['snapshot_migrations']:4d} "
                  f"{row['warm_starts']:5d} {row['restore_starts']:7d} "
                  f"{row['cold_starts']:5d} {row['host_seconds']:8.3f} "
                  f"{p99:8.2f}")
        print("\nBursts eat the fleet's free-unit slack through the"
              "\nlow-water mark, so the autoscaler boots hosts; the quiet"
              "\ntail holds slack at the high-water mark until the"
              "\nemptiest host retires.  A retiring host stops taking"
              "\nroutes, drains its snapshot pool to peers over the"
              "\ncontended interconnect (concurrent transfers sharing an"
              "\nendpoint split its bandwidth), and is removed only once"
              "\nits ledger shows every unit back home — warm state"
              "\nsurvives scale-down instead of being discarded.")
        return

    if args.scenario is not None:
        import json

        from repro.cluster.scenarios import SCENARIOS, run_scenario
        assert args.scenario in SCENARIOS, \
            f"unknown scenario {args.scenario!r} " \
            f"(have {', '.join(sorted(SCENARIOS))})"
        row = run_scenario(args.scenario, seed=args.seed)
        print(json.dumps(row, indent=1))
        return

    cfg = reduced(get_config("qwen2-7b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=128, n_partitions=8,
                                block_tokens=32)
    bpp = spec.blocks_per_partition
    # the snapshot pool is paid for by the policies that exploit it —
    # and always on a fleet, where it is what migration moves
    pooled = args.policy in ("snapshot_affinity", "drain_weighted",
                             "slo_tiered") or args.hosts > 1
    pool_units = 4 * bpp if pooled else None
    # one replica per host (min 2, so the steal/pinned scenario exists)
    rids = [chr(ord("A") + k) for k in range(max(2, args.hosts))]

    print(f"policy={args.policy} hosts={args.hosts}")
    print(f"{'mode':10s} {'broker':6s} {'completed':>9s} {'steals':>6s} "
          f"{'stall_p99_ms':>12s} {'steal_ms':>9s} {'migratedKiB':>11s} "
          f"{'lat_p99_s':>9s} {'warm':>5s} {'restore':>7s} {'remote':>6s} "
          f"{'mig':>4s} {'squeezed':>8s}")
    for mode in args.modes.split(","):
        for async_mode in (False, True):
            # single host: 10 partitions' worth — less than 2 full arenas,
            # so A's burst cannot grow without shrinking B (or squeezing
            # the snapshot pool first, when one exists).  Fleet: each
            # host holds a full arena's budget (uncontended — the
            # cross-host traffic is snapshots, not steals).
            budget = (10 if args.hosts == 1 else 12) * bpp
            topo = DeviceTopology.uniform(budget, args.devices) \
                if args.devices > 1 else None
            sched = FleetScheduler()
            for k in range(args.hosts):
                sched.add_host(f"h{k}", HostMemoryBroker(
                    budget_units=budget, async_reclaim=async_mode,
                    snapshot_pool_units=pool_units, topology=topo))
            start_units = min(2, spec.n_partitions) * bpp
            hosts_map = {h: {} for h in sched.brokers}
            for i, rid in enumerate(rids):
                host = sched.place(rid, start_units, policy="spread")
                hosts_map[host][rid] = ServeEngine(
                    cfg, params, spec, mode=mode, keep_alive=3.0, seed=i,
                    broker=sched.brokers[host], replica_id=rid,
                    snapshot_page_bytes=args.page_size)
            if args.policy == "pinned":
                router = Router(route_fn=lambda r, e:
                                "B" if r.rid.startswith("b") else "A")
            else:
                router = Router(args.policy)
            if args.hosts == 1:
                sim = ClusterSim(hosts_map["h0"], router,
                                 sched.brokers["h0"])
            else:
                sim = FleetSim(hosts_map, router, scheduler=sched)
            m = sim.run(_reqs(pooled), max_virtual_s=2000)
            sched.check_invariants()
            reps = [b.report() for b in sched.brokers.values()]
            by_mode = [r["by_mode"].get(mode, {}) for r in reps]
            stalls = sum((b.request_stalls for b in
                          sched.brokers.values()), []) or [0.0]
            print(f"{mode:10s} {'async' if async_mode else 'sync':6s} "
                  f"{m['completed']:9d} "
                  f"{sum(r['steals'] for r in reps):6d} "
                  f"{float(np.percentile(stalls, 99)) * 1e3:12.2f} "
                  f"{sum(d.get('wall_seconds', 0.0) for d in by_mode) * 1e3:9.2f} "
                  f"{sum(d.get('migrated_bytes', 0) for d in by_mode) / 1024:11.1f} "
                  f"{(m['latency_p99'] or 0):9.2f} "
                  f"{m['warm_hits']:5d} {m['restore_starts']:7d} "
                  f"{m['remote_restore_starts']:6d} "
                  f"{m['snapshot_migrations']:4d} "
                  f"{sum(r['squeezed_units'] for r in reps):8d}")
            if args.devices > 1:
                # per-device occupancy: free/granted/snapshot units on
                # every device of each host's mesh at end of run
                for h, b in sorted(sched.brokers.items()):
                    cols = b.ledger.device_report()
                    occ = "  ".join(
                        f"d{d}[free={c['free']} granted={c['granted']} "
                        f"snap={c['snapshot']}]"
                        for d, c in enumerate(cols))
                    print(f"{'':17s} {h}: {occ}")
    print("\nThe broker reclaims the idle replica's memory for the loaded"
          "\none; HotMem makes that host-level steal zero-copy, the paged"
          "\nbaseline pays real migration bytes for the same elasticity —"
          "\nand the async reclaim pipeline removes the requester-visible"
          "\nstall entirely (stall_p99 -> 0): victims drain ReclaimOrders"
          "\nbetween their own ticks while the requester keeps decoding."
          "\nWith --policy snapshot_affinity or drain_weighted the host"
          "\nalso pools expired warm containers' prefix KV: later"
          "\ninvocations restore from the pool instead of prefilling, and"
          "\nunder pressure the broker squeezes those snapshot units"
          "\nfirst (metadata-only) before ordering any VM to shrink."
          "\nWith --hosts N the fleet scheduler places replicas across"
          "\nper-host budgets and migrates snapshots between hosts (mig)"
          "\nso a host that never ran a function restores its warm state"
          "\nremotely (remote) — paying the modeled inter-host copy,"
          "\nstill far below a cold prefill.")


if __name__ == "__main__":
    main()
