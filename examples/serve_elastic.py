"""End-to-end driver (paper scenario): elastic multi-tenant serving under a
bursty serverless trace, comparing HotMem vs vanilla vs static.

  PYTHONPATH=src python examples/serve_elastic.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


def main() -> None:
    print(f"{'mode':10s} {'completed':>9s} {'p99(s)':>8s} "
          f"{'reclaimedKiB':>12s} {'migratedKiB':>11s} {'reclaim(s)':>10s}")
    for mode in ("hotmem", "vanilla", "static"):
        _, m = serve("qwen2-7b", mode=mode, duration=16.0, rate=0.8,
                     n_partitions=8, partition_tokens=128, keep_alive=3.0)
        print(f"{mode:10s} {m['completed']:9d} "
              f"{(m['latency_p99'] or 0):8.2f} "
              f"{m['reclaimed_bytes']/1024:12.1f} "
              f"{m['migrated_bytes']/1024:11.1f} "
              f"{m['reclaim_wall_s']:10.4f}")
    print("\nHotMem reclaims the same bytes with ZERO migration (the paper's"
          "\norder-of-magnitude reclaim win) at P99 comparable to static"
          "\nover-provisioning.")


if __name__ == "__main__":
    main()
