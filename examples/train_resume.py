"""Fault-tolerant training: checkpoint, crash, resume — the restart path a
1000-node deployment exercises on every preemption.

  PYTHONPATH=src python examples/train_resume.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train
from repro.training import checkpoint as ckpt


def main() -> None:
    with tempfile.TemporaryDirectory() as d:
        print("=== run 1: crash injected at step 8 ===")
        try:
            train("tinyllama-1.1b", steps=12, batch=2, seq=32, ckpt_dir=d,
                  ckpt_every=4, fail_at_step=8, log_every=4)
        except RuntimeError as e:
            print(f"crashed: {e}")
        print(f"latest complete checkpoint: step {ckpt.latest(d)}")
        print("=== run 2: --resume ===")
        _, losses = train("tinyllama-1.1b", steps=12, batch=2, seq=32,
                          ckpt_dir=d, ckpt_every=4, resume=True, log_every=4)
        print(f"resumed and finished; final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
