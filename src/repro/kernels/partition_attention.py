"""Pallas TPU kernel: decode attention over contiguous HotMem partitions.

The HotMem fast path.  Each request's KV lives contiguously in its partition
row, so the kernel streams (BT, Dh) tiles of K/V straight from HBM into VMEM
with sequential DMAs — no gather, no block-table indirection (contrast with
``paged_attention``).  Online-softmax accumulation over KV tiles (flash
decoding); ring-cache masking for windowed layers.

Grid: (P, Hkv, T // BT) — partitions and KV heads parallel, KV tiles
sequential (accumulator in VMEM scratch).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG_INF = -2.0 ** 30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bt: int, t: int, n_t: int, window: int, cap: float,
            scale: float):
    pi = pl.program_id(0)
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (G, Dh)
    k = k_ref[0, :, 0, :]                             # (BT, Dh)
    v = v_ref[0, :, 0, :]
    pos = pos_ref[pi]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=f32) * scale   # (G, BT)
    if cap:
        s = jnp.tanh(s / cap) * cap
    slots = ti * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
    gidx = pos - ((pos - slots) % t)                  # ring: global index
    valid = gidx >= 0
    if window:
        valid &= gidx > pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=f32)
    m_ref[...] = m_new

    @pl.when(ti == n_t - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def partition_attention(q, k_cache, v_cache, positions, *, window: int = 0,
                        logit_cap: float = 0.0, scale: float | None = None,
                        block_t: int = 512, interpret: bool = True):
    """q (P, Hkv, G, Dh); k/v_cache (P, T, Hkv, Dh); positions (P,) int32.
    Returns (P, Hkv, G, Dh)."""
    p, hkv, g, dh = q.shape
    t = k_cache.shape[1]
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    n_t = t // bt
    if scale is None:
        scale = dh ** -0.5

    kernel = functools.partial(_kernel, bt=bt, t=t, n_t=n_t, window=window,
                               cap=logit_cap, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p, hkv, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda pi, h, ti, pos: (pi, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, dh), lambda pi, h, ti, pos:
                         (pi, ti, h, 0)),
            pl.BlockSpec((1, bt, 1, dh), lambda pi, h, ti, pos:
                         (pi, ti, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda pi, h, ti, pos:
                               (pi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), f32),      # running max
            pltpu.VMEM((g, 1), f32),      # running denominator
            pltpu.VMEM((g, dh), f32),     # output accumulator
        ],
    )
    from repro.kernels.ops import tpu_compiler_params
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, hkv, g, dh), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(positions.astype(jnp.int32), q, k_cache, v_cache)
