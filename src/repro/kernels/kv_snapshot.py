"""Pallas TPU kernels: fused snapshot gather-capture / scatter-restore.

The snapshot data plane used to pay one dispatch per cache leaf: capture
sliced every leaf of an arena row (``cache_read_row``) and ``device_get``
materialized each slice as its own transfer; restore ran one ``.at[].set``
per leaf.  These kernels collapse a whole row (or a batch of rows) into
ONE launch each:

  capture — grid step ``i`` gathers every leaf's ``rows[i]`` slice into a
            single contiguous staging blob ``(n_rows, row_elems)``.  The
            blob's byte image is exactly the leaf-order concatenation of
            each slice's C-order bytes — the same layout the engine's
            paginator hashes — so one ``device_get`` of the blob is the
            entire device->host cost and pagination never re-copies.
  restore — the inverse scatter: grid step ``i`` carves the blob row back
            into every leaf at ``rows[i]``.  The leaves are donated
            (input/output aliased), so untouched rows stay in place — the
            same in-place discipline as ``kv_compact``.

Rows are scalar-prefetched so every leaf's index map can chase them
(``PrefetchScalarGridSpec``, the ``kv_compact`` pattern).  Leaf offsets
into the blob are static (baked into the kernel body from ``RowLayout``),
so the body is pure static slicing — no dynamic addressing beyond the
row index maps.

Roofline contract (the dace ``RooflineModel`` wrapper pattern: every
kernel gets an analytic model and measurements are checked against it):
``capture_cost``/``restore_cost`` predict the bytes each launch must move
from the *cache specs alone*; the device benchmark publishes expected vs
measured bytes per (shape x page size) cell and the ``BENCH_10.json``
gate fails if they ever drift apart by more than 2x.

TPU caveat: blocks are whole per-leaf row slices (e.g. ``(G,1,T,H,D)``),
sized well under VMEM for arena partitions but not tiled to the (16,128)
bf16 sublane grid; off-TPU the kernels run in interpret mode (the only
mode this CPU container exercises), on TPU Mosaic pads the odd tails.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import roofline


# ---------------------------------------------------------------------------
# Row layout: the flat byte image of one arena row
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One cache leaf's slice of the staging blob."""
    axis: int                    # leaf batch (row) axis
    block_shape: tuple           # leaf shape with the batch extent -> 1
    size: int                    # elements of one row slice
    offset: int                  # element offset into the blob row


@dataclasses.dataclass(frozen=True)
class RowLayout:
    """Static description of a cache tree's per-row staging blob.

    Per-row slice shapes do not depend on the arena row count (only the
    batch extent varies), so one layout is valid across every bucket of
    the ladder.  Hashable -> usable as a jit static argument."""
    slots: tuple                 # tuple[LeafSlot, ...] in tree-flatten order
    dtype: str                   # shared leaf dtype (cache trees are bf16)
    total_elems: int

    @property
    def itemsize(self) -> int:
        import numpy as np
        return np.dtype(self.dtype).itemsize

    @property
    def row_bytes(self) -> int:
        return self.total_elems * self.itemsize

    def signature(self) -> tuple:
        """Shape/dtype fingerprint stored in snapshot payloads so a
        restore can assert the blob still matches the live cache tree."""
        return tuple((s.block_shape, self.dtype) for s in self.slots)


def build_layout(leaves: Sequence[Any], axes: Sequence[int]) -> RowLayout:
    """Layout from (leaf, batch_axis) pairs (arrays or tracers)."""
    assert len(leaves) == len(axes) and leaves
    dtypes = {str(x.dtype) for x in leaves}
    assert len(dtypes) == 1, \
        f"fused snapshot blob needs one leaf dtype, got {sorted(dtypes)}"
    slots, off = [], 0
    for x, ax in zip(leaves, axes):
        shape = tuple(x.shape)
        block = shape[:ax] + (1,) + shape[ax + 1:]
        size = math.prod(block)
        slots.append(LeafSlot(axis=ax, block_shape=block, size=size,
                              offset=off))
        off += size
    return RowLayout(slots=tuple(slots), dtype=dtypes.pop(),
                     total_elems=off)


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------


def _slot_index_map(slot: LeafSlot):
    """Block index map chasing the scalar-prefetched row list: the batch
    axis follows ``rows[i]``, every other axis is covered by the block."""
    def index_map(i, rows, _axis=slot.axis, _nd=len(slot.block_shape)):
        return tuple(rows[i] if j == _axis else 0 for j in range(_nd))
    return index_map


def snapshot_capture(leaves, rows, *, layout: RowLayout,
                     interpret: bool = True):
    """Gather ``rows`` of every cache leaf into one staging blob.

    leaves: flat cache leaves (tree-flatten order of the cache tree);
    rows (N,) int32 arena row ids.  Returns ``(N, layout.total_elems)``
    in the shared leaf dtype — ONE kernel launch for all leaves x rows.
    """
    n = rows.shape[0]

    def kernel(rows_ref, *refs):
        del rows_ref
        out = refs[-1]
        for slot, ref in zip(layout.slots, refs[:-1]):
            out[0, slot.offset:slot.offset + slot.size] = \
                ref[...].reshape((slot.size,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec(slot.block_shape, _slot_index_map(slot))
                  for slot in layout.slots],
        out_specs=pl.BlockSpec((1, layout.total_elems),
                               lambda i, rows: (i, 0)),
    )
    from repro.kernels.ops import tpu_compiler_params
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, layout.total_elems),
                                       jnp.dtype(layout.dtype)),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(rows.astype(jnp.int32), *leaves)


def snapshot_restore(leaves, blob, rows, *, layout: RowLayout,
                     interpret: bool = True):
    """Scatter blob rows back into every cache leaf at ``rows`` — the
    exact inverse of ``snapshot_capture``, one launch, leaves donated
    (aliased) so untouched rows stay in place.  Returns the new leaves.
    """
    n = rows.shape[0]
    n_leaves = len(layout.slots)

    def kernel(rows_ref, blob_ref, *refs):
        del rows_ref
        outs = refs[n_leaves:]
        for slot, out in zip(layout.slots, outs):
            out[...] = blob_ref[
                0, slot.offset:slot.offset + slot.size
            ].reshape(slot.block_shape)

    leaf_specs = [pl.BlockSpec(slot.block_shape, _slot_index_map(slot))
                  for slot in layout.slots]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, layout.total_elems),
                               lambda i, rows: (i, 0))] + leaf_specs,
        out_specs=leaf_specs,
    )
    from repro.kernels.ops import tpu_compiler_params
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tuple(x.shape), x.dtype)
                   for x in leaves],
        # operand k (after 1 scalar arg + 1 blob) aliases output k
        input_output_aliases={2 + k: k for k in range(n_leaves)},
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(rows.astype(jnp.int32), blob, *leaves)


# ---------------------------------------------------------------------------
# Roofline bytes models (analytic — from specs, never from live arrays)
# ---------------------------------------------------------------------------


def expected_row_bytes(cfg, partition_tokens: int) -> int:
    """Bytes of one arena row's staging blob, derived from the cache
    SPECS (an independent code path from the live layout, so a silent
    layout change shows up as expected-vs-measured drift)."""
    import numpy as np
    from repro.models.model import cache_specs
    from repro.models.layers import tree_map_specs
    total = 0

    def acc(s):
        nonlocal total
        total += math.prod(s.shape) * np.dtype(s.dtype).itemsize

    tree_map_specs(acc, cache_specs(cfg, 1, partition_tokens))
    return total


def capture_cost(row_bytes: int, n_rows: int) -> dict[str, float]:
    """Bytes one fused capture launch must move: read every leaf slice,
    write the blob (HBM), then one device->host copy of the blob."""
    hbm = 2.0 * n_rows * row_bytes
    d2h = float(n_rows * row_bytes)
    return {"hbm_bytes": hbm, "host_bytes": d2h,
            "memory_s": hbm / roofline.HBM_BW}


def restore_cost(row_bytes: int, n_rows: int,
                 new_fraction: float = 1.0) -> dict[str, float]:
    """Bytes one fused restore moves: host->device only for the pages not
    already mapped (CoW), then blob read + leaf scatter write in HBM."""
    hbm = 2.0 * n_rows * row_bytes
    h2d = float(n_rows * row_bytes) * new_fraction
    return {"hbm_bytes": hbm, "host_bytes": h2d,
            "memory_s": hbm / roofline.HBM_BW}


# ---------------------------------------------------------------------------
# Data-plane accounting (dispatch / transfer counters the tests assert on)
# ---------------------------------------------------------------------------

STATS = {
    "capture_launches": 0,       # fused capture executions
    "restore_launches": 0,       # fused restore executions
    "d2h_transfers": 0,          # device->host copies (capture readout)
    "d2h_bytes": 0,
    "h2d_transfers": 0,          # host->device copies (restore staging)
    "h2d_bytes": 0,
    "remap_restores": 0,         # fully-mapped CoW restores (zero h2d)
}


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


def note_launch(kind: str) -> None:
    STATS[f"{kind}_launches"] += 1


def note_d2h(nbytes: int) -> None:
    STATS["d2h_transfers"] += 1
    STATS["d2h_bytes"] += int(nbytes)


def note_h2d(nbytes: int) -> None:
    STATS["h2d_transfers"] += 1
    STATS["h2d_bytes"] += int(nbytes)


def note_remap() -> None:
    STATS["remap_restores"] += 1
