"""Pallas TPU kernel: KV block migration (compaction) — the cost HotMem
eliminates.

Copies ``count`` live blocks from the pool tail into free head slots before
a vanilla arena shrink: pool[dst[i]] <- pool[src[i]].  One grid step per
move streams a whole (BT, Hkv, Dh) block HBM->VMEM->HBM; the move list is
scalar-prefetched so both index maps chase it.  The pool is donated
(input/output aliased) so untouched blocks stay in place.

This is the TPU analogue of Linux page migration: its bytes scale with
occupancy, it burns HBM bandwidth, and it runs *between* decode steps —
the interference the paper's Fig. 7/10 measure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(src_ref, dst_ref, pool_ref, out_ref):
    del src_ref, dst_ref
    out_ref[...] = pool_ref[...]


def kv_compact(pool, src, dst, *, interpret: bool = True):
    """pool (NB, BT, Hkv, Dh); src/dst (M,) int32 move list (pad unused
    entries with src=dst so they degenerate to self-copies).
    Returns the compacted pool."""
    m = src.shape[0]
    nb = pool.shape[0]
    blk = (1,) + pool.shape[1:]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                         # src, dst
        grid=(m,),
        in_specs=[
            pl.BlockSpec(blk, lambda i, s, d: (s[i],) + (0,) *
                         (len(blk) - 1)),
        ],
        out_specs=pl.BlockSpec(blk, lambda i, s, d: (d[i],) + (0,) *
                               (len(blk) - 1)),
    )
    from repro.kernels.ops import tpu_compiler_params
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},   # pool (after 2 scalar args) -> out
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), pool)
