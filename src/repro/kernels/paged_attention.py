"""Pallas TPU kernel: decode attention over the vanilla paged KV layout.

The state-of-practice path the paper measures against.  K/V blocks are
scattered across a shared pool; the block table (scalar-prefetched so the
index map can chase it) drives a gather-style DMA per KV tile.  Same online-
softmax math as ``partition_attention`` — the layout indirection is the only
difference, which is exactly the HotMem-vs-vanilla contrast at kernel level.

Grid: (P, Hkv, MB) — one step per (request, kv head, table slot).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG_INF = -2.0 ** 30


def _kernel(tab_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, bt: int, n_b: int, cap: float, scale: float):
    pi = pl.program_id(0)
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[pi]
    mapped = tab_ref[pi, bi] >= 0

    @pl.when(mapped)
    def _step():
        q = q_ref[0, 0]                                # (G, Dh)
        k = k_ref[0, :, 0, :]                          # (BT, Dh)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32) * scale
        if cap:
            s = jnp.tanh(s / cap) * cap
        tok = bi * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
        s = jnp.where(tok <= pos, s, NEG_INF)          # linear fill
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=f32)
        m_ref[...] = m_new

    @pl.when(bi == n_b - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention(q, k_pool, v_pool, tables, positions, *,
                    logit_cap: float = 0.0, scale: float | None = None,
                    interpret: bool = True):
    """q (P, Hkv, G, Dh); k/v_pool (NB, BT, Hkv, Dh); tables (P, MB) int32
    (-1 = unmapped); positions (P,).  Returns (P, Hkv, G, Dh)."""
    p, hkv, g, dh = q.shape
    nb, bt = k_pool.shape[:2]
    mb = tables.shape[1]
    if scale is None:
        scale = dh ** -0.5

    kernel = functools.partial(_kernel, bt=bt, n_b=mb, cap=logit_cap,
                               scale=scale)

    def kv_index(pi, h, bi, tab, pos):
        return (jnp.maximum(tab[pi, bi], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                         # tables, positions
        grid=(p, hkv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda pi, h, bi, tab, pos: (pi, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, dh), kv_index),
            pl.BlockSpec((1, bt, 1, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda pi, h, bi, tab, pos: (pi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), f32),
            pltpu.VMEM((g, 1), f32),
            pltpu.VMEM((g, dh), f32),
        ],
    )
    from repro.kernels.ops import tpu_compiler_params
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, hkv, g, dh), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables.astype(jnp.int32), positions.astype(jnp.int32), q, k_pool,
      v_pool)
