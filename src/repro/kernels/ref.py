"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32
NEG_INF = -2.0 ** 30


def _softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


def partition_attention(q, k_cache, v_cache, positions, *, window: int = 0,
                        logit_cap: float = 0.0, scale: float | None = None):
    """Decode attention over contiguous (HotMem partition) KV rows.

    q: (P, Hkv, G, Dh); k/v_cache: (P, T, Hkv, Dh) ring caches;
    positions: (P,) global position of the current token (already written).
    Returns (P, Hkv, G, Dh).
    """
    p, t = k_cache.shape[:2]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    slots = jnp.arange(t, dtype=jnp.int32)[None, :]
    gidx = positions[:, None] - ((positions[:, None] - slots) % t)
    valid = gidx >= 0
    if window:
        valid &= gidx > positions[:, None] - window
    s = jnp.einsum("bkgd,btkd->bkgt", q, k_cache,
                   preferred_element_type=f32) * scale
    s = _softcap(s, logit_cap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", w.astype(v_cache.dtype), v_cache)


def paged_attention(q, k_pool, v_pool, tables, positions, *,
                    logit_cap: float = 0.0, scale: float | None = None):
    """Decode attention over the vanilla paged layout.

    q: (P, Hkv, G, Dh); k/v_pool: (NB, BT, Hkv, Dh);
    tables: (P, MB) int32 block ids (-1 = unmapped);
    positions: (P,) current token position (token i lives in logical block
    i // BT at offset i % BT — linear fill, no ring).
    """
    nb, bt = k_pool.shape[:2]
    mb = tables.shape[1]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    k_rows = k_pool[jnp.maximum(tables, 0)]          # (P, MB, BT, Hkv, Dh)
    v_rows = v_pool[jnp.maximum(tables, 0)]
    sh = (tables.shape[0], mb * bt) + k_pool.shape[2:]
    k_rows = k_rows.reshape(sh)
    v_rows = v_rows.reshape(sh)
    tok = jnp.arange(mb * bt, dtype=jnp.int32)[None, :]
    valid = (tok <= positions[:, None]) & \
        (jnp.repeat(tables, bt, axis=1) >= 0)
    s = jnp.einsum("bkgd,btkd->bkgt", q, k_rows,
                   preferred_element_type=f32) * scale
    s = _softcap(s, logit_cap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", w.astype(v_rows.dtype), v_rows)


def kv_compact(pool, src, dst, count):
    """Migration oracle: pool[dst[i]] = pool[src[i]] for i < count."""
    live = jnp.arange(src.shape[0]) < count
    sdst = jnp.where(live, dst, pool.shape[0])
    return pool.at[sdst].set(pool[jnp.where(live, src, 0)], mode="drop")


def snapshot_capture(leaves, rows, layout):
    """Fused-capture oracle: per-row slices of every leaf, concatenated in
    tree-flatten order into a (N, row_elems) blob.  The blob's byte image
    matches the legacy per-leaf ``tobytes()`` concatenation (a size-1 batch
    axis never changes C order), so digests are stable across paths."""
    n = rows.shape[0]
    parts = []
    for leaf, slot in zip(leaves, layout.slots):
        sl = jnp.moveaxis(jnp.take(leaf, rows, axis=slot.axis), slot.axis, 0)
        parts.append(sl.reshape(n, slot.size))
    return jnp.concatenate(parts, axis=1)


def snapshot_restore(leaves, blob, rows, layout):
    """Fused-restore oracle: scatter blob rows back into every leaf at
    ``rows``; untouched rows pass through."""
    n = rows.shape[0]
    outs = []
    for leaf, slot in zip(leaves, layout.slots):
        chunk = blob[:, slot.offset:slot.offset + slot.size]
        rest = slot.block_shape[:slot.axis] + slot.block_shape[slot.axis + 1:]
        vals = jnp.moveaxis(chunk.reshape((n,) + rest), 0, slot.axis)
        idx = (slice(None),) * slot.axis + (rows,)
        outs.append(leaf.at[idx].set(vals.astype(leaf.dtype)))
    return outs
