"""Public jit'd wrappers over the Pallas kernels.

On TPU the Pallas path runs compiled (``interpret=False``); everywhere else
(this CPU container, unit tests) the same kernel body executes in interpret
mode, validated against the ``ref.py`` oracles.  ``impl="ref"`` selects the
pure-jnp oracle — the serving engine uses it for timed CPU benchmarks where
interpret-mode tracing overhead would drown the signal.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Compat shim over the ``pltpu.CompilerParams`` -> ``TPUCompilerParams``
    rename: build whichever class this JAX ships.  Kernel modules import this
    lazily (inside their builder functions) to avoid an import cycle."""
    cls = getattr(pltpu, "TPUCompilerParams", None) or \
        getattr(pltpu, "CompilerParams")
    return cls(**kwargs)


from repro.kernels import ref
from repro.kernels.kv_compact import kv_compact as _kv_compact_kernel
from repro.kernels.kv_snapshot import (
    snapshot_capture as _snapshot_capture_kernel,
    snapshot_restore as _snapshot_restore_kernel,
)
from repro.kernels.paged_attention import paged_attention as _paged_kernel
from repro.kernels.partition_attention import \
    partition_attention as _partition_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "logit_cap", "scale",
                                             "impl"))
def partition_attention(q, k_cache, v_cache, positions, *, window=0,
                        logit_cap=0.0, scale=None, impl="pallas"):
    if impl == "ref":
        return ref.partition_attention(q, k_cache, v_cache, positions,
                                       window=window, logit_cap=logit_cap,
                                       scale=scale)
    return _partition_kernel(q, k_cache, v_cache, positions, window=window,
                             logit_cap=logit_cap, scale=scale,
                             interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("logit_cap", "scale", "impl"))
def paged_attention(q, k_pool, v_pool, tables, positions, *, logit_cap=0.0,
                    scale=None, impl="pallas"):
    if impl == "ref":
        return ref.paged_attention(q, k_pool, v_pool, tables, positions,
                                   logit_cap=logit_cap, scale=scale)
    return _paged_kernel(q, k_pool, v_pool, tables, positions,
                         logit_cap=logit_cap, scale=scale,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("impl",))
def kv_compact(pool, src, dst, *, impl="pallas"):
    if impl == "ref":
        count = src.shape[0]
        return ref.kv_compact(pool, src, dst, count)
    return _kv_compact_kernel(pool, src, dst, interpret=not _on_tpu())


# Module-level jits: one dispatch cache shared by every engine instance, so
# the first TIMED snapshot in any engine reuses a compile paid session-wide
# (the engine additionally pre-warms per shape before its timed region).

@functools.partial(jax.jit, static_argnames=("layout", "impl"))
def kv_snapshot_capture(leaves, rows, *, layout, impl="pallas"):
    """All leaves x rows -> one (N, row_elems) staging blob, one launch."""
    leaves = tuple(leaves)
    if impl == "ref":
        return ref.snapshot_capture(leaves, rows, layout)
    return _snapshot_capture_kernel(leaves, rows, layout=layout,
                                    interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("layout", "impl"))
def kv_snapshot_restore(leaves, blob, rows, *, layout, impl="pallas"):
    """Inverse scatter: blob rows -> every leaf at ``rows``, one launch.
    Returns the new leaves tuple (kernel path aliases leaves in place on
    TPU, same discipline as ``kv_compact``)."""
    leaves = tuple(leaves)
    if impl == "ref":
        return tuple(ref.snapshot_restore(leaves, blob, rows, layout))
    return tuple(_snapshot_restore_kernel(leaves, blob, rows, layout=layout,
                                          interpret=not _on_tpu()))
