"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes to
mesh axes, with divisibility-aware fallbacks.

Model code annotates tensors with *logical* axis names via ``shard(x, ...)``;
the active :class:`ShardCtx` (mesh + rule table) decides the physical
``PartitionSpec``.  With no active context (single-device smoke tests)
``shard`` is a no-op, so the same model code runs everywhere.

Fallback policy: a rule only applies if every mesh axis it names exists in
the mesh.  If the dimension is not divisible by the mesh-axis product, the
rule applies anyway (GSPMD pads) only for axes in ``PAD_OK`` — head/expert
counts like 28 heads over a 16-way "model" axis, where padding (+14% FLOPs)
beats losing tensor parallelism.  Everything else falls back to replication.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = dict[str, tuple[str, ...] | str | None]

# Strict divisibility everywhere: NamedSharding rejects uneven dims for
# input specs, and GSPMD's uneven-padding fallback for *constraints* causes
# involuntary full rematerialization (replicate + repartition) of layer-
# sized tensors — e.g. padding 4 kv heads to 16 swamped the collective
# roofline term.  Head-count dims that don't divide the axis fall back to
# replication; the projection *weights* still shard via their flattened
# (heads*head_dim) dims, which are 128-multiples throughout the pool.
PAD_OK: frozenset = frozenset()

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

TRAIN_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,             # activation d_model
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",        # overridden to None for moe_sharding="tp"
    "expert_mlp": None,        # overridden to "model" for moe_sharding="tp"
    "expert_cap": ("pod", "data"),
    "vocab": "model",
    "w_embed": ("pod", "data"),  # weight d_model dim: FSDP / ZeRO-3
    "kv_seq": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "lru": "model",
    "layers": None,
    "src": None,
    # remat-saved scan boundaries; "model" for wide models shards the saved
    # residual stream over seq (sequence-parallel checkpoint storage)
    "seq_remat": None,
}

# Serving: weights TP-only (latency), caches sequence-sharded over "model"
# (kv head counts rarely divide 16; sequence always does at 32k+).
SERVE_RULES: Rules = dict(
    TRAIN_RULES,
    w_embed=None,
    kv_seq="model",
    kv_heads=None,           # cache kv-head dim replicated; seq carries TP
)

# Serving for models whose bf16 weights exceed ~8 GiB/chip at TP=16: 2D
# tensor parallelism.  Weights shard d_model over "data" AND heads/ffn over
# "model"; activations shard d_model over "data" too, so projections
# contract over the sharded dim and pay a tiny per-token activation psum
# instead of re-all-gathering GBs of weights every decode step.
SERVE_BIG_RULES: Rules = dict(SERVE_RULES, w_embed=("pod", "data"))

# long_500k context-parallel decode: batch==1, so the KV sequence takes both
# axes (524288 / 256 = 2048 per chip).
SERVE_CP_RULES: Rules = dict(SERVE_RULES, kv_seq=("data", "model"),
                             batch=None)


def serve_rules_for(cfg, shape_name: str) -> Rules:
    rules = SERVE_RULES
    if cfg.param_count() * 2 / 16 > 8 * 2 ** 30:  # >8 GiB bf16/chip at TP=16
        rules = SERVE_BIG_RULES
    if shape_name == "long_500k":
        rules = dict(rules, kv_seq=("data", "model"), batch=None)
    if cfg.moe_sharding == "tp":
        rules = dict(rules, experts=None, expert_mlp="model")
    return rules


# Beyond-paper (hillclimbed): small models on a 256-chip pod should not pay
# Megatron-TP activation all-reduces at all — pure data/FSDP parallelism
# over every mesh axis moves only the (small) weights, not activations.
DP_ONLY_TRAIN_RULES: Rules = dict(
    TRAIN_RULES,
    batch=("pod", "data", "model"),
    heads=None, kv_heads=None, mlp=None, vocab=None,
    experts=None, expert_mlp=None, expert_cap=("pod", "data", "model"),
    ssm_heads=None, ssm_inner=None, lru=None,
    w_embed=("pod", "data", "model"),
)

# bf16 weights per chip below which pure-FSDP beats TP on this pod
_DP_ONLY_MAX_BYTES = 4 * 2 ** 30


def train_rules_for(cfg, *, dp_only: bool | None = None) -> Rules:
    if dp_only is None:
        dp_only = cfg.param_count() * 2 <= _DP_ONLY_MAX_BYTES \
            and cfg.num_experts == 0
    if dp_only:
        return DP_ONLY_TRAIN_RULES
    rules = TRAIN_RULES
    if cfg.moe_sharding == "tp":
        rules = dict(rules, experts=None, expert_mlp="model")
    if cfg.d_model >= 6144:
        # wide models: saved scan boundaries alone exceed the activation
        # budget at microbatch 1 — store them sequence-sharded over "model"
        # (one (B,S,D) all-gather per group per pass buys back GBs of HBM)
        rules = dict(rules, seq_remat="model")
    return rules


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------


class ShardCtx:
    def __init__(self, mesh: Mesh, rules: Rules):
        self.mesh = mesh
        self.rules = rules

    def axis_size(self, names: tuple[str, ...]) -> int:
        return math.prod(self.mesh.shape.get(n, 1) for n in names)


_CTX: contextvars.ContextVar[Optional[ShardCtx]] = contextvars.ContextVar(
    "shard_ctx", default=None)


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Rules):
    tok = _CTX.set(ShardCtx(mesh, rules) if mesh is not None else None)
    try:
        yield
    finally:
        _CTX.reset(tok)


def current_ctx() -> Optional[ShardCtx]:
    return _CTX.get()


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------


def spec_for(axes: tuple[Optional[str], ...], shape: tuple[int, ...],
             ctx: ShardCtx) -> P:
    assert len(axes) == len(shape), (axes, shape)
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, axes):
        entry = ctx.rules.get(name) if name else None
        if entry is None:
            parts.append(None)
            continue
        mesh_axes = (entry,) if isinstance(entry, str) else tuple(entry)
        mesh_axes = tuple(a for a in mesh_axes
                          if a in ctx.mesh.shape and a not in used)
        if not mesh_axes:
            parts.append(None)
            continue
        n = ctx.axis_size(mesh_axes)
        if dim % n != 0:
            # try a prefix of the axes (e.g. batch over ("pod","data"))
            while mesh_axes and dim % ctx.axis_size(mesh_axes) != 0:
                mesh_axes = mesh_axes[:-1]
            if not mesh_axes:
                parts.append(None)
                continue
        used.update(mesh_axes)
        parts.append(mesh_axes[0] if len(mesh_axes) == 1 else mesh_axes)
    return P(*parts)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o context)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = spec_for(axes, x.shape, ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(axes: tuple[Optional[str], ...], shape: tuple[int, ...],
                   mesh: Mesh, rules: Rules) -> NamedSharding:
    return NamedSharding(mesh, spec_for(axes, shape, ShardCtx(mesh, rules)))


# ---------------------------------------------------------------------------
# Memory-control-plane view
# ---------------------------------------------------------------------------


def mesh_topology(mesh: Mesh, budget_per_device: int):
    """The host broker's view of ``mesh``: a uniform ``DeviceTopology``
    (``repro.cluster.topology``) with one account of
    ``budget_per_device`` broker units per mesh device.  A replica whose
    KV is sharded over this mesh holds one unit shard per device, so the
    ledger's per-device conservation law tracks real per-chip HBM, not
    one fictional flat pool."""
    from repro.cluster.topology import DeviceTopology

    assert budget_per_device > 0
    return DeviceTopology(budgets=(budget_per_device,) * mesh.size)
