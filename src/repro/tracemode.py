"""Analysis-mode tracing switches.

``analysis_mode()`` retraces the model for roofline *accounting* rather than
execution: layer-group scans unroll (XLA's HloCostAnalysis counts while
bodies once, not x trip-count) and chunked-flash attention is swapped for
its plain equivalent (identical FLOPs, no inner scan).  The resulting
lowering is never executed or even compiled — ``lowered.cost_analysis()``
reads the unoptimized module.  Combined with depth extrapolation (lower at
1 and 2 groups, extend linearly — exact because groups are identical) this
gives artifact-derived FLOPs/bytes at full depth in seconds.
"""
from __future__ import annotations

import contextlib
import contextvars

_ANALYSIS = contextvars.ContextVar("analysis_mode", default=False)


@contextlib.contextmanager
def analysis_mode():
    tok = _ANALYSIS.set(True)
    try:
        yield
    finally:
        _ANALYSIS.reset(tok)


def is_analysis() -> bool:
    return _ANALYSIS.get()


def scan_unroll() -> bool | int:
    return True if _ANALYSIS.get() else 1
