"""SeamlessM4T-medium backbone: encoder-decoder, multimodal.
[arXiv:2308.11596; hf]

The speech/text modality frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, src_len, d_model)
for the encoder; the text decoder is exercised by the shape cells.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,                 # decoder layers
    encoder_layers=12,
    encoder_src_len=1024,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,               # MHA
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,             # padded to 2048-multiple when vocab-sharded
    block_pattern=("encdec",),
    act="gelu",
    norm_eps=1e-5,
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-medium",
))
