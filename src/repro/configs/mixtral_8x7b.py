"""Mixtral-8x7B: 8 experts top-2, sliding-window attention.  [arXiv:2401.04088]

8 experts do not divide the 16-way "model" axis -> tensor-parallel expert
FFNs (TP over d_ff) instead of EP.  SWA makes decode state O(window), so the
long_500k cell runs with a ring-buffer KV cache.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    moe_sharding="tp",
    act="silu",
    source="arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1",
))
