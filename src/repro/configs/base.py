"""Model/shape configuration registry.

One ``ModelConfig`` describes any architecture in the assigned pool (dense /
MoE / VLM / SSM / audio enc-dec / hybrid).  Per-arch modules under
``repro/configs`` register themselves into ``ARCHS``; ``SHAPES`` holds the
assigned input-shape cells.  ``reduced()`` derives the CPU-smoke-test config
for an arch (same family, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# ---------------------------------------------------------------------------
# Shapes (assigned): every (arch x shape) cell is defined by these four.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int       # decoder-side sequence length (KV length for decode)
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | ssm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention variants -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()     # qwen2-vl M-RoPE (t, h, w)
    sliding_window: int = 0                  # SWA window; 0 = full attention
    # layer pattern within one scanned group, e.g. ("local", "global") for
    # gemma2 or ("rglru", "rglru", "attn") for recurrentgemma.  Dense archs
    # use a single-entry group.  ``tail_pattern`` holds unscanned trailing
    # blocks when num_layers % len(pattern) != 0.
    block_pattern: tuple[str, ...] = ("attn",)
    tail_pattern: tuple[str, ...] = ()
    local_window: int = 0                    # window for "local" blocks
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    query_scale: float = 0.0                 # 0 => 1/sqrt(head_dim)

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    moe_sharding: str = "ep"                 # "ep" | "tp"

    # --- SSM (mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (recurrentgemma RG-LRU) --------------------------------------
    lru_width: int = 0

    # --- enc-dec (seamless) ---------------------------------------------------
    encoder_layers: int = 0
    encoder_src_len: int = 1024              # stub frame-embedding length

    # --- misc -----------------------------------------------------------------
    act: str = "silu"                        # "silu" | "gelu"
    norm_eps: float = 1e-6
    post_norms: bool = False                 # gemma2 sandwich norms
    tie_embeddings: bool = False
    scale_embeddings: bool = False           # gemma-style sqrt(d_model)
    vision_stub_tokens: int = 0              # vlm: injected patch embeddings
    source: str = ""                         # provenance tag

    # ------------------------------------------------------------------ utils
    @property
    def n_groups(self) -> int:
        body = self.num_layers - len(self.tail_pattern)
        assert body % len(self.block_pattern) == 0, (
            f"{self.name}: {body} body layers not divisible by "
            f"pattern {self.block_pattern}")
        return body // len(self.block_pattern)

    @property
    def d_inner(self) -> int:                # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True when decode state is sub-linear in context (assigned rule:
        run long_500k for SSM / hybrid / windowed / local-global archs)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window > 0:
            return True
        return "local" in self.block_pattern  # alternating local/global
    # Encoder-only archs would skip decode shapes entirely; every assigned
    # arch has a decoder, so no such skip exists in this pool.

    def cells(self) -> list[str]:
        out = []
        for s in SHAPES.values():
            if s.name == "long_500k" and not self.supports_long_context:
                continue
            out.append(s.name)
        return out

    def param_count(self) -> int:
        """Exact parameter count from the spec tree."""
        from repro.models.model import param_specs
        import math
        return sum(math.prod(s.shape)
                   for _, s in _iter_specs(param_specs(self), True))

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts top-k experts only)."""
        total = self.param_count()
        if self.num_experts:
            from repro.models.model import param_specs
            import math
            expert, active = 0, 0
            for path, s in _iter_specs(param_specs(self), True):
                # expert-stacked ffn weights carry E at dim -3
                if "/ffn/" in path and len(s.shape) >= 3 \
                        and s.shape[-3] == self.num_experts:
                    n = math.prod(s.shape)
                    expert += n
                    active += n * self.num_experts_per_tok \
                        // self.num_experts
            total = total - expert + active
        return total


def _iter_specs(tree, with_path: bool = False, path: str = ""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_specs(v, with_path, f"{path}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_specs(v, with_path, f"{path}/{i}")
    else:
        yield (path, tree) if with_path else tree


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCHS: dict[str, ModelConfig] = {}
_REDUCERS: dict[str, Callable[[ModelConfig], ModelConfig]] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return ARCHS[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(ARCHS)


def _ensure_loaded() -> None:
    if len(ARCHS) >= 10:
        return
    import importlib
    for mod in ("qwen2_7b", "gemma2_9b", "tinyllama_1_1b", "qwen2_1_5b",
                "dbrx_132b", "mixtral_8x7b", "qwen2_vl_72b", "mamba2_780m",
                "seamless_m4t_medium", "recurrentgemma_2b"):
        importlib.import_module(f"repro.configs.{mod}")


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    pat = len(cfg.block_pattern)
    layers = pat * 2 + len(cfg.tail_pattern)
    kv = min(cfg.num_kv_heads, 2)
    heads = max(4, kv)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2)
        if cfg.num_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else cfg.ssm_headdim,
        ssm_chunk=8,
        lru_width=64 if cfg.lru_width else 0,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        local_window=min(cfg.local_window, 8) if cfg.local_window else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_src_len=16 if cfg.encoder_layers else cfg.encoder_src_len,
        mrope_sections=(4, 2, 2) if cfg.mrope_sections else (),
        vision_stub_tokens=4 if cfg.vision_stub_tokens else 0,
    )
