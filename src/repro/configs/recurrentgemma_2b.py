"""RecurrentGemma-2B: RG-LRU recurrent blocks + local attention, 1:2 ratio.
[arXiv:2402.19427; hf]

Pattern (rglru, rglru, local) x 8 + (rglru, rglru) tail = 26 blocks.
Decode state is O(window) -> runs long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,                # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    rope_theta=10_000.0,
    block_pattern=("rglru", "rglru", "local"),
    tail_pattern=("rglru", "rglru"),
    local_window=2048,
    lru_width=2560,
    final_logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    scale_embeddings=True,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
))
