"""Gemma2-9B: alternating local/global attention, logit softcaps, sandwich
norms, tied embeddings.  [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,                # decoupled from d_model/num_heads
    d_ff=14336,
    vocab_size=256000,
    rope_theta=10_000.0,
    block_pattern=("local", "global"),
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=256.0 ** -0.5,   # query_pre_attn_scalar = 256
    act="gelu",
    post_norms=True,
    tie_embeddings=True,
    scale_embeddings=True,
    source="arXiv:2408.00118; hf:google/gemma-2-9b",
))
