"""Mamba2-780M: attention-free SSD (state-space duality).  [arXiv:2405.21060]

Decode state is O(1) in context length -> the best case for HotMem
partitions (constant, tiny per-request partitions); runs long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssm",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_conv_width=4,
    ssm_chunk=256,
    norm_eps=1e-5,
    tie_embeddings=True,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-780m (unverified)",
))
