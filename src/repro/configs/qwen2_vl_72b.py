"""Qwen2-VL-72B backbone: M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings that are scattered into the token stream.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # (temporal, height, width) half-dims
    vision_stub_tokens=256,
    act="silu",
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B",
))
