"""Sharded, manifest-committed, async checkpointing with elastic reshard.

Layout:  <dir>/step_<N>/  shard_<host>.npz  +  MANIFEST.json  (written last;
a checkpoint without a manifest is incomplete and ignored by ``latest``).
Writes go to ``step_<N>.tmp`` then atomically rename — a crash mid-save
never corrupts the restore path (fault-tolerance contract: restart always
finds the newest *complete* step).

Restore is mesh-agnostic: arrays are loaded on host and ``device_put`` with
the *target* shardings, so a checkpoint taken on mesh A restores onto mesh B
(elastic rescale after losing/gaining pods).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            yield from _flatten(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _unflatten_into(template, flat: dict[str, np.ndarray], prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}/{k}")
                for k, v in sorted(template.items())}
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}/{i}")
            for i, v in enumerate(template))
    return flat[prefix]


def _encode(arr: np.ndarray) -> tuple[str, np.ndarray]:
    """npz cannot store bfloat16 — view as uint16 with a key marker."""
    if arr.dtype.itemsize == 2 and arr.dtype.kind == "V" or \
            str(arr.dtype) == "bfloat16":
        return "::bf16", arr.view(np.uint16)
    return "", arr


def _decode(key: str, arr: np.ndarray) -> tuple[str, np.ndarray]:
    if key.endswith("::bf16"):
        import ml_dtypes
        return key[:-6], arr.view(ml_dtypes.bfloat16)
    return key, arr


def save(ckpt_dir: str, step: int, state, *, process_index: int = 0,
         blocking: bool = True) -> threading.Thread:
    """Write one host's shard + manifest; async when blocking=False."""
    arrays = {}
    for k, v in _flatten(state):
        suffix, enc = _encode(np.asarray(v))
        arrays[k + suffix] = enc

    def _write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_{process_index}.npz"), **arrays)
        manifest = {"step": step, "time": time.time(),
                    "keys": sorted(arrays), "hosts": 1}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic commit

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def latest(ckpt_dir: str) -> Optional[int]:
    """Newest *complete* (manifest-committed) step, or None."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, "MANIFEST.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template, *,
            shardings: Optional[Any] = None, process_index: int = 0):
    """Load into the template's structure; reshard onto ``shardings`` (a
    matching tree of NamedSharding) when given — elastic mesh changes."""
    path = os.path.join(ckpt_dir, f"step_{step}",
                        f"shard_{process_index}.npz")
    with np.load(path) as z:
        flat = dict(_decode(k, z[k]) for k in z.files)
    state = _unflatten_into(template, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state
