"""Train step: microbatched grad accumulation + AdamW, donation-friendly.

``grad_accum`` splits the global batch into microbatches scanned on-device
(fp32 grad accumulator), bounding saved-activation memory to one microbatch
— the knob that keeps every assigned train_4k cell under 16 GiB/chip
(verified by the dry-run's memory_analysis).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import cross_entropy, f32
from repro.models.model import forward_train
from repro.training.optimizer import AdamWConfig, apply_updates


def _loss_fn(cfg, params, batch):
    logits = forward_train(cfg, params, batch, remat=True)
    return cross_entropy(cfg, logits, batch["labels"])


def _split_micro(batch, accum: int):
    """(B, ...) -> (A, B/A, ...) for every leaf."""
    def sp(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape((accum, b // accum) + x.shape[1:])
    # frames/vision leaves reshape on batch too; positions built inside
    return jax.tree.map(sp, batch)


def make_train_step(cfg, opt: Optional[AdamWConfig] = None,
                    grad_accum: int = 1):
    opt = opt or AdamWConfig()

    def train_step(state: dict[str, Any], batch: dict[str, jax.Array]):
        params = state["params"]

        if grad_accum == 1:
            loss, grads = jax.value_and_grad(
                lambda p: _loss_fn(cfg, p, batch))(params)
        else:
            micro = _split_micro(batch, grad_accum)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(
                    lambda p: _loss_fn(cfg, p, mb))(params)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(f32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, f32), params)
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, jnp.zeros((), f32)),
                                            micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum

        new_params, new_opt, metrics = apply_updates(
            opt, params, grads, state["opt"])
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_batch_labels(tokens: jax.Array) -> dict[str, jax.Array]:
    """Next-token prediction: labels are tokens shifted left."""
    return {"tokens": tokens,
            "labels": jnp.concatenate(
                [tokens[:, 1:], tokens[:, :1]], axis=1)}
