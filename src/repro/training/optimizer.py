"""AdamW in pure JAX with fp32 master weights over bf16 compute params.

Gradients flow in bf16 end-to-end (the compressed-collective trick: the
cross-data-parallel all-reduce moves half the bytes) and are accumulated /
applied in fp32 against the master copy; bf16 params are re-derived each
step.  m/v are fp32, sharded identically to the params (ZeRO-3 style via
the same logical axes), so optimizer state scales with the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> dict[str, Any]:
    # m/v derive from params (x*0) rather than jnp.zeros so every leaf is a
    # DISTINCT device buffer — jnp.zeros dedupes identical constants, and
    # donating the same buffer twice (m and v of one param) is an error.
    zeros = lambda p: jax.tree.map(lambda x: x.astype(f32) * 0, p)
    return {
        "step": jnp.zeros((), jnp.int32),
        # + 0.0 forces a copy: astype(f32) is a no-op view for params that
        # are already f32 (norm scales), and master must not share buffers
        # with the donated params
        "master": jax.tree.map(lambda x: x.astype(f32) + 0.0, params),
        "m": zeros(params),
        "v": zeros(params),
    }


def _schedule(opt: AdamWConfig, step):
    warm = jnp.minimum(step / max(opt.warmup_steps, 1), 1.0)
    return opt.lr * warm


def apply_updates(opt: AdamWConfig, params, grads, state):
    """One AdamW step; returns (new bf16 params, new state, metrics)."""
    step = state["step"] + 1
    lr = _schedule(opt, step.astype(f32))

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(f32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, opt.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if opt.grad_clip else 1.0

    b1c = 1.0 - opt.b1 ** step.astype(f32)
    b2c = 1.0 - opt.b2 ** step.astype(f32)

    def upd(g, m, v, w):
        g = g.astype(f32) * scale
        m = opt.b1 * m + (1 - opt.b1) * g
        v = opt.b2 * v + (1 - opt.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + opt.eps)
        w = w - lr * (u + opt.weight_decay * w)
        return m, v, w

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_w = jax.tree.leaves(state["master"])
    new = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    m_t = treedef.unflatten([n[0] for n in new])
    v_t = treedef.unflatten([n[1] for n in new])
    w_t = treedef.unflatten([n[2] for n in new])
    params_t = jax.tree.map(
        lambda w, p: w.astype(p.dtype), w_t, params)
    return params_t, {"step": step, "master": w_t, "m": m_t, "v": v_t}, {
        "grad_norm": gnorm, "lr": lr}
