"""Deterministic, shardable synthetic data pipeline.

Batches are a pure function of (seed, step, shard) — no files, no state —
which gives exact resume-after-restart (the checkpoint stores only the step)
and host-sharded loading for multi-host meshes: each host materializes only
its slice of the global batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


class SyntheticTokens:
    """Zipf-ish token stream with document structure (deterministic)."""

    def __init__(self, cfg: DataConfig, num_shards: int = 1,
                 shard_id: int = 0):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard_id = shard_id
        self.shard_batch = cfg.global_batch // num_shards
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._probs = p / p.sum()

    def batch_at(self, step: int) -> np.ndarray:
        """(shard_batch, seq_len) int32 tokens for a given global step."""
        rng = np.random.default_rng(
            (self.cfg.seed, step, self.shard_id))
        return rng.choice(
            self.cfg.vocab_size, p=self._probs,
            size=(self.shard_batch, self.cfg.seq_len)).astype(np.int32)

    def labels_at(self, step: int, tokens: np.ndarray) -> np.ndarray:
        return np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
