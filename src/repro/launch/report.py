"""Generate EXPERIMENTS.md §Dry-run and §Roofline from dry-run JSONL logs.

  PYTHONPATH=src python -m repro.launch.report \
      --inputs dryrun_results.jsonl dryrun_fixes.jsonl --out EXPERIMENTS.md

Later files win per (arch, shape, mesh) — fix re-runs supersede the sweep.
"""
from __future__ import annotations

import argparse
import json
from typing import Any

from repro.configs.base import SHAPES

GiB = 2 ** 30


def load(paths: list[str]) -> dict[tuple, dict]:
    cells: dict[tuple, dict] = {}
    for path in paths:
        try:
            with open(path) as f:
                for line in f:
                    try:
                        r = json.loads(line)
                    except json.JSONDecodeError:
                        continue
                    cells[(r["arch"], r["shape"], r["mesh"])] = r
        except FileNotFoundError:
            continue
    return cells


def _ms(x: float) -> str:
    return f"{x*1e3:.2f}"


def _lever(r: dict) -> str:
    rl = r["roofline"]
    coll = r.get("collectives", {}).get("bytes_by_op", {})
    top = max(coll, key=coll.get) if coll else ""
    if rl["bound"] == "collective":
        if "all-reduce" in top:
            return ("cut TP activation all-reduces (seq-shard between "
                    "attn/mlp, or trade model-axis for fsdp)")
        if "all-gather" in top:
            return "amortize/overlap FSDP weight gathers or drop fsdp axis"
        return f"reduce {top} volume"
    if rl["bound"] == "memory":
        if r["shape"].startswith("decode") or r["shape"] == "long_500k":
            return ("weight/cache bytes dominate: quantize weights or raise "
                    "batch to amortize reads")
        return "raise arithmetic intensity (fuse, larger microbatch)"
    return "near compute roofline: cut recompute/padding waste"


def render(cells: dict[tuple, dict]) -> tuple[str, str]:
    archs = sorted({a for a, _, _ in cells})
    shapes = [s for s in SHAPES]

    # ---------------- §Dry-run -----------------
    dr = ["## §Dry-run\n",
          "Every (arch x shape) cell lowered + compiled with "
          "`jax.jit(step).lower(**input_specs).compile()` on BOTH production "
          "meshes (16x16 single-pod, 2x16x16 multi-pod; 512 host devices). "
          "`peak GiB` = memory_analysis() args+out+temps-aliased, minus the "
          "quantified CPU-backend f32-weight-upcast artifact (bf16 matmuls "
          "are native on TPU; see §Methodology).  Budget: 16 GiB/chip "
          "(TPU v5e).\n",
          "| arch | shape | 16x16 | peak GiB | 2x16x16 | peak GiB | "
          "collectives (1-pod, /chip/step) |",
          "|---|---|---|---|---|---|---|"]
    for a in archs:
        for s in shapes:
            r1 = cells.get((a, s, "16x16"))
            r2 = cells.get((a, s, "2x16x16"))
            if r1 is None and r2 is None:
                continue

            def cell_str(r):
                if r is None:
                    return "—", ""
                if r.get("skipped"):
                    return "skip", "—"
                ok = "OK" if r.get("ok") else "FAIL"
                if not r.get("ok"):
                    return ok, "—"
                pk = r["memory"].get("peak_tpu_estimate",
                                     r["memory"].get("peak_bytes", 0))
                fits = "" if r.get("fits_hbm") else " (!)"
                return ok, f"{pk/GiB:.2f}{fits}"

            s1, p1 = cell_str(r1)
            s2, p2 = cell_str(r2)
            collstr = ""
            if r1 and r1.get("ok") and not r1.get("skipped"):
                c = r1["collectives"]
                parts = [f"{k.split('-')[-1] if False else k}="
                         f"{v/GiB:.2f}GiB" for k, v in
                         c["bytes_by_op"].items() if v > 0]
                collstr = " ".join(parts[:3])
            dr.append(f"| {a} | {s} | {s1} | {p1} | {s2} | {p2} | "
                      f"{collstr} |")
    n_ok = sum(1 for r in cells.values() if r.get("ok"))
    n_skip = sum(1 for r in cells.values() if r.get("skipped"))
    dr.append(f"\n**{n_ok}/{len(cells)} cells OK** ({n_skip} assigned "
              "long_500k skips for pure full-attention archs, per "
              "DESIGN.md §Arch-applicability).\n")

    # ---------------- §Roofline -----------------
    ro = ["## §Roofline\n",
          "Per (arch x shape), single-pod 16x16 mesh (256 chips; "
          "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI link/chip).  "
          "`compute/memory/coll` are the three roofline terms in ms "
          "(per-chip).  `useful` = MODEL_FLOPS / HLO_FLOPs "
          "(6·N·D train, 2·N_active·D inference).  `frac` = fraction of "
          "the compute roofline achieved at the modelled bound "
          "(useful-FLOPs time / max-term).\n",
          "| arch | shape | compute ms | memory ms | coll ms | bound | "
          "useful | frac | lever |",
          "|---|---|---|---|---|---|---|---|---|"]
    for a in archs:
        for s in shapes:
            r = cells.get((a, s, "16x16"))
            if not r or not r.get("ok") or r.get("skipped"):
                continue
            rl = r["roofline"]
            ro.append(
                f"| {a} | {s} | {_ms(rl['compute_s'])} | "
                f"{_ms(rl['memory_s'])} | {_ms(rl['collective_s'])} | "
                f"{rl['bound']} | {rl['useful_ratio']:.2f} | "
                f"{rl['roofline_fraction']:.3f} | {_lever(r)} |")
    return "\n".join(dr), "\n".join(ro)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inputs", nargs="+",
                    default=["dryrun_results.jsonl", "dryrun_fixes.jsonl"])
    ap.add_argument("--print", dest="do_print", action="store_true")
    args = ap.parse_args()
    cells = load(args.inputs)
    dr, ro = render(cells)
    print(dr)
    print()
    print(ro)


if __name__ == "__main__":
    main()
