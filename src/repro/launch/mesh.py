"""Production mesh construction (single-pod 16x16 and 2-pod 2x16x16).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over local devices (tests / examples)."""
    n = jax.device_count()
    return jax.make_mesh((1, n), ("data", "model"))


def make_host_topology(budget_per_device: int):
    """The memory-control-plane view of THIS host's devices: a uniform
    ``DeviceTopology`` with ``budget_per_device`` broker units (blocks)
    of HBM budget on each local device.  Feed it to ``HostMemoryBroker``
    so grants/reclaim/snapshots stripe over the real local mesh."""
    from repro.cluster.topology import DeviceTopology

    assert budget_per_device > 0
    return DeviceTopology(budgets=(budget_per_device,)
                          * jax.device_count())
