"""ShapeDtypeStruct stand-ins for every (architecture x shape) cell.

``input_specs`` returns weak-type-correct, sharded, zero-allocation inputs
for the step function each cell lowers:

  * train_4k          -> train_step(state, batch)
  * prefill_32k       -> prefill_step(params, batch, caches)
  * decode_32k/500k   -> serve_step(params, tokens, positions, caches)

Modality frontends are stubs per the assignment: VLM cells carry precomputed
patch embeddings, audio cells precomputed frame embeddings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeCell
from repro.models import model as M
from repro.models.layers import bf16
from repro.sharding import Rules, named_sharding

i32 = jnp.int32

# saved-boundary activation budget per chip for remat'd train cells
_SAVED_ACT_BUDGET = 2 * 2 ** 30


def _sds(shape, dtype, axes, mesh, rules):
    sh = named_sharding(axes, shape, mesh, rules) if mesh is not None \
        else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def scan_boundaries(cfg: ModelConfig) -> int:
    if cfg.family == "audio":
        return cfg.num_layers + cfg.encoder_layers
    return cfg.n_groups


def default_grad_accum(cfg: ModelConfig, cell: ShapeCell, mesh,
                       rules=None) -> int:
    """Pick grad-accum so saved scan boundaries fit the activation budget.
    Every extra accum step re-pays the per-microbatch FSDP weight gathers,
    so when seq_remat shards the saved boundaries over "model" the budget
    stretches 16x and accum (hence gather traffic) drops accordingly."""
    batch_axes = ("pod", "data")
    if rules is not None and rules.get("batch") is not None:
        b = rules["batch"]
        batch_axes = (b,) if isinstance(b, str) else tuple(b)
    batch_shards = math.prod(
        mesh.shape.get(a, 1) for a in batch_axes) if mesh else 1
    per_dev = max(cell.global_batch // batch_shards, 1)
    per_mb_bytes = scan_boundaries(cfg) * cell.seq_len * cfg.d_model * 2
    if rules is not None and rules.get("seq_remat") and mesh is not None:
        ax = rules["seq_remat"]
        per_mb_bytes //= math.prod(
            mesh.shape.get(a, 1)
            for a in ((ax,) if isinstance(ax, str) else ax))
    if cfg.family in ("ssm", "hybrid"):
        # recurrence backward passes materialize fp32 coefficient arrays
        per_mb_bytes *= 4
    mb_max = max(int(_SAVED_ACT_BUDGET // per_mb_bytes), 1)
    accum = max(1, -(-per_dev // mb_max))
    while per_dev % accum and accum < per_dev:
        accum += 1
    return min(accum, per_dev)


def train_state_specs(cfg: ModelConfig, mesh, rules):
    params = M.abstract_params(cfg, mesh=mesh, rules=rules)

    def f32_like(t):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                           sharding=s.sharding), t)
    return {
        "params": params,
        "opt": {
            "step": jax.ShapeDtypeStruct((), i32),
            "master": f32_like(params),
            "m": f32_like(params),
            "v": f32_like(params),
        },
    }


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh, rules,
                labels: bool):
    b, s = cell.global_batch, cell.seq_len
    out: dict[str, Any] = {
        "tokens": _sds((b, s), i32, ("batch", "seq"), mesh, rules)}
    if labels:
        out["labels"] = _sds((b, s), i32, ("batch", "seq"), mesh, rules)
    if cfg.family == "vlm":
        out["vision_embeds"] = _sds(
            (b, cfg.vision_stub_tokens, cfg.d_model), bf16,
            ("batch", None, "embed"), mesh, rules)
    if cfg.family == "audio":
        out["frames"] = _sds((b, cfg.encoder_src_len, cfg.d_model), bf16,
                             ("batch", "src", "embed"), mesh, rules)
    return out


def input_specs(arch_or_cfg, shape_name: str, mesh=None,
                rules: Rules | None = None) -> dict[str, Any]:
    """All inputs for one cell's step function, as sharded SDS trees."""
    from repro.configs.base import get_config
    from repro.sharding import serve_rules_for, train_rules_for
    cfg = arch_or_cfg if isinstance(arch_or_cfg, ModelConfig) else \
        get_config(arch_or_cfg)
    cell = SHAPES[shape_name]
    if rules is None and mesh is not None:
        rules = (train_rules_for(cfg) if cell.kind == "train"
                 else serve_rules_for(cfg, shape_name))

    if cell.kind == "train":
        return {
            "state": train_state_specs(cfg, mesh, rules),
            "batch": batch_specs(cfg, cell, mesh, rules, labels=True),
        }

    b = cell.global_batch
    caches = M.abstract_caches(cfg, b, cell.seq_len, mesh=mesh, rules=rules)
    params = M.abstract_params(cfg, mesh=mesh, rules=rules)
    if cell.kind == "prefill":
        return {
            "params": params,
            "batch": batch_specs(cfg, cell, mesh, rules, labels=False),
            "caches": caches,
        }
    # decode: one new token against a seq_len-deep cache
    return {
        "params": params,
        "tokens": _sds((b, 1), i32, ("batch", None), mesh, rules),
        "positions": _sds((b,), i32, ("batch",), mesh, rules),
        "caches": caches,
    }
