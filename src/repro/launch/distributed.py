"""Multi-host bring-up and elastic-restart wrappers.

On a real pod slice each host runs the same launcher under this harness:

    repro.launch.distributed.initialize() -> jax.distributed.initialize()
    make_production_mesh() lays ("pod","data","model") over the global
    device set; per-host data loading uses SyntheticTokens(num_shards=
    process_count, shard_id=process_index); checkpoints shard per host
    (training/checkpoint.py already writes shard_<process>.npz).

Fault tolerance at fleet scale composes three contracts this repo tests on
one host:
  * restart-from-manifest (tests/test_checkpoint.py::test_crash_resume_*)
  * reshard-on-load for elastic world sizes (::test_elastic_reshard_on_load)
  * pure-function data cursors (no pipeline state to replay)

``run_with_restarts`` is the supervision loop a cluster agent wraps around
the trainer: bounded restarts, exponential backoff, resume always on.
"""
from __future__ import annotations

import os
import time
from typing import Callable


def initialize(coordinator: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None) -> tuple[int, int]:
    """jax.distributed bring-up (no-op on single host).  Returns
    (process_index, process_count)."""
    import jax
    coordinator = coordinator or os.environ.get("REPRO_COORDINATOR")
    if coordinator:
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=num_processes
            or int(os.environ.get("REPRO_NUM_PROCESSES", "1")),
            process_id=process_id
            or int(os.environ.get("REPRO_PROCESS_ID", "0")))
    return jax.process_index(), jax.process_count()


def run_with_restarts(fn: Callable[[], None], *, max_restarts: int = 16,
                      backoff_s: float = 5.0) -> None:
    """Supervise ``fn`` (a --resume-capable trainer) through failures.
    Each restart resumes from the newest manifest-committed checkpoint;
    data cursors are step-indexed so no input state is lost."""
    attempt = 0
    while True:
        try:
            fn()
            return
        except KeyboardInterrupt:
            raise
        except Exception as e:                      # noqa: BLE001
            attempt += 1
            if attempt > max_restarts:
                raise
            wait = min(backoff_s * 2 ** (attempt - 1), 300.0)
            print(f"[supervise] attempt {attempt} failed ({e!r}); "
                  f"restarting in {wait:.0f}s")
            time.sleep(wait)


def hedged_dispatch(replicas, submit: Callable, *, deadline_s: float):
    """Straggler mitigation for serving (design contract, exercised in
    tests/test_serving_hedge.py): submit to the least-loaded replica and
    hedge to a second one if no first token arrives before ``deadline_s``
    (typically the fleet P99 TTFT).  Returns the chosen replica indices."""
    order = sorted(range(len(replicas)),
                   key=lambda i: replicas[i].load())
    primary = order[0]
    t = submit(primary)
    if t is not None and t <= deadline_s:
        return [primary]
    backup = order[1] if len(order) > 1 else primary
    submit(backup)
    return [primary, backup]
