"""Serving launcher: trace-driven elastic serving on any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --mode hotmem --duration 20 --rate 1.0

Runs the ServeEngine (paper §4.1 analogue) against a bursty synthetic trace
and prints the reclaim/latency metrics the paper's Figs. 8–10 report.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.base import get_config, reduced
from repro.core.arena import ArenaSpec
from repro.models import model as M
from repro.serving.engine import ServeEngine
from repro.serving.request import PROFILES, Request
from repro.serving.tracegen import assign_profiles, bursty_trace


def serve(arch: str, *, mode: str = "hotmem", duration: float = 20.0,
          rate: float = 1.0, n_partitions: int = 8,
          partition_tokens: int = 128, keep_alive: float = 3.0,
          use_reduced: bool = True, seed: int = 0):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = ArenaSpec.from_model(cfg, partition_tokens=partition_tokens,
                                n_partitions=n_partitions, block_tokens=32)
    arrivals = bursty_trace(duration, rate, burst_x=6.0, burst_at=(0.0,),
                            burst_len=duration / 6,
                            quiet_after=duration / 2, seed=seed)
    reqs = [Request(rid=f"r{i}", profile=p, submit_s=t)
            for i, (t, p) in enumerate(
                assign_profiles(arrivals, PROFILES, seed))]
    eng = ServeEngine(cfg, params, spec, mode=mode, keep_alive=keep_alive,
                      seed=seed)
    metrics = eng.run(reqs, max_virtual_s=duration * 40)
    return eng, metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="hotmem",
                    choices=["hotmem", "vanilla", "static"])
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--partitions", type=int, default=8)
    ap.add_argument("--partition-tokens", type=int, default=128)
    ap.add_argument("--keep-alive", type=float, default=3.0)
    ap.add_argument("--reduced", action="store_true")
    a = ap.parse_args()
    _, m = serve(a.arch, mode=a.mode, duration=a.duration, rate=a.rate,
                 n_partitions=a.partitions,
                 partition_tokens=a.partition_tokens,
                 keep_alive=a.keep_alive, use_reduced=a.reduced)
    m.pop("events")
    print(json.dumps(m, indent=2, default=str))


if __name__ == "__main__":
    main()
