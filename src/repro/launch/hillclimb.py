import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb runner: the three selected cells, baseline vs each
hypothesis (EXPERIMENTS.md §Perf records the full loop).

  PYTHONPATH=src python -m repro.launch.hillclimb --out hillclimb.jsonl
"""
import argparse
import json

from repro.launch.dryrun import run_cell
from repro.sharding import (DP_ONLY_TRAIN_RULES, TRAIN_RULES,
                            train_rules_for)
from repro.configs.base import get_config


def experiments():
    # ---- Cell A: dbrx-132b x train_4k (most collective-bound) ----------
    dbrx = get_config("dbrx-132b")
    yield ("dbrx-132b", "train_4k",
           dict(tag="A0-baseline", rules=dict(TRAIN_RULES, seq_remat=None),
                grad_accum=16))
    yield ("dbrx-132b", "train_4k",
           dict(tag="A1-seqremat-accum1",
                rules=train_rules_for(dbrx, dp_only=False)))
    yield ("dbrx-132b", "train_4k",
           dict(tag="A2-seqremat-accum4",
                rules=train_rules_for(dbrx, dp_only=False), grad_accum=4))
    # A3 (flash-train + accum1) REFUTED: scan-backward under remat still
    # saves per-chunk probabilities, O(S^2) f32 — see EXPERIMENTS.md §Perf.
    # A4: accum=8 — the fitting point on the gather-vs-activation frontier
    yield ("dbrx-132b", "train_4k",
           dict(tag="A4-seqremat-accum8",
                rules=train_rules_for(dbrx, dp_only=False), grad_accum=8))

    # ---- Cell B: tinyllama-1.1b x train_4k (worst train frac / TP
    #      all-reduce pathology, representative of all small-arch cells) --
    yield ("tinyllama-1.1b", "train_4k", dict(tag="B0-baseline",
                                              rules=TRAIN_RULES))
    yield ("tinyllama-1.1b", "train_4k", dict(tag="B1-dp-only",
                                              rules=DP_ONLY_TRAIN_RULES))

    # ---- Cell C: qwen2-7b x decode_32k (paper-representative: decode on
    #      the HotMem partition arena; memory-bound) ----------------------
    yield ("qwen2-7b", "decode_32k", dict(tag="C0-baseline"))
    yield ("qwen2-7b", "decode_32k", dict(tag="C1-int8-weights",
                                          quant=True))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="hillclimb.jsonl")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    for arch, shape, kw in experiments():
        if args.only and args.only not in kw["tag"]:
            continue
        rec = run_cell(arch, shape, **kw)
        rec.pop("traceback", None)
        rl = rec.get("roofline", {})
        print(f"  -> {kw['tag']}: bound={rl.get('bound')} "
              f"compute={rl.get('compute_s', 0)*1e3:.1f}ms "
              f"memory={rl.get('memory_s', 0)*1e3:.1f}ms "
              f"coll={rl.get('collective_s', 0)*1e3:.1f}ms "
              f"frac={rl.get('roofline_fraction', 0):.4f}")
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
