"""Training launcher: any assigned arch, fault-tolerant, mesh-aware.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 10 [--resume]

Fault-tolerance contract (tested in tests/test_fault_tolerance.py):
  * checkpoints are manifest-committed (atomic rename) + async;
  * --resume restores the newest complete step and the data pipeline
    cursor is a pure function of the step — restart-safe;
  * restore reshards onto the *current* mesh (elastic rescale after node
    loss: a checkpoint from mesh A loads onto mesh B).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import model as M
from repro.sharding import sharding_ctx, train_rules_for
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticTokens
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 128,
          use_reduced: bool = True, ckpt_dir: str | None = None,
          ckpt_every: int = 10, resume: bool = False, grad_accum: int = 1,
          lr: float = 3e-4, mesh=None, log_every: int = 10,
          fail_at_step: int | None = None):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    rules = train_rules_for(cfg) if mesh is not None else {}

    data = SyntheticTokens(DataConfig(cfg.vocab_size, seq, batch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    start_step = 0
    if resume and ckpt_dir and (last := ckpt.latest(ckpt_dir)) is not None:
        state = ckpt.restore(ckpt_dir, last, state)
        state = jax.tree.map(jnp.asarray, state)   # host arrays -> device
        start_step = last
        print(f"[train] resumed from step {last}")

    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=lr),
                                      grad_accum=grad_accum),
                      donate_argnums=(0,))
    pending_save = None
    losses = []
    with sharding_ctx(mesh, rules):
        for step in range(start_step, steps):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            toks = data.batch_at(step)
            b = {"tokens": jnp.asarray(toks),
                 "labels": jnp.asarray(data.labels_at(step, toks))}
            if cfg.family == "vlm":
                b["vision_embeds"] = jnp.zeros(
                    (batch, cfg.vision_stub_tokens, cfg.d_model),
                    jnp.bfloat16)
            if cfg.family == "audio":
                b["frames"] = jnp.zeros(
                    (batch, cfg.encoder_src_len, cfg.d_model), jnp.bfloat16)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, b)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                if pending_save is not None:
                    pending_save.join()          # one in-flight save
                pending_save = ckpt.save(ckpt_dir, step + 1, state,
                                         blocking=False)
    if pending_save is not None:
        pending_save.join()
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    a = ap.parse_args()
    _, losses = train(a.arch, steps=a.steps, batch=a.batch, seq=a.seq,
                      use_reduced=a.reduced, ckpt_dir=a.ckpt_dir,
                      ckpt_every=a.ckpt_every, resume=a.resume,
                      grad_accum=a.grad_accum, lr=a.lr)
    print(f"[train] done; loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
