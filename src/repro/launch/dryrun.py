import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x shape) cell on
# the production meshes and extract roofline inputs.
#
# The two lines above MUST run before ANY jax import (jax locks the device
# count at first init); 512 placeholder host devices back the 2x16x16 mesh.

"""Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape decode_32k [--multi-pod] [--out results.jsonl]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell this lowers the real step function (train_step with optimizer, or
prefill/serve step with donated caches), compiles it, and records
memory_analysis() (proves it fits 16 GiB/chip), cost_analysis() FLOPs/bytes,
and the collective schedule parsed from the compiled HLO.
"""

import argparse
import json
import math
import time
import traceback
from typing import Any

import jax

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import default_grad_accum, input_specs
from repro.roofline import (Roofline, cost_flops_bytes, hbm_traffic_model,
                            model_flops_per_chip, parse_collective_bytes)
from repro.sharding import serve_rules_for, sharding_ctx, train_rules_for

HBM_PER_CHIP = 16 * 2 ** 30          # TPU v5e


def _memory_stats(compiled) -> dict[str, float]:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": float(m.argument_size_in_bytes),
            "output_bytes": float(m.output_size_in_bytes),
            "temp_bytes": float(m.temp_size_in_bytes),
            "alias_bytes": float(m.alias_size_in_bytes),
            "peak_bytes": float(m.argument_size_in_bytes
                                + m.output_size_in_bytes
                                + m.temp_size_in_bytes
                                - m.alias_size_in_bytes),
        }
    except Exception as e:                      # backend without support
        return {"error": repr(e)}


def f32_weight_upcast_bytes(hlo_text: str, cfg, mesh, rules) -> int:
    """CPU-backend artifact: XLA-CPU emulates bf16 matmuls by hoisting f32
    copies of the (stacked, sharded) weight operands; TPU MXUs consume bf16
    natively, so these temps don't exist on the target.  Sum the f32 tensors
    in the compiled module whose shapes exactly match bf16 param shards."""
    import re as _re
    from repro.models.layers import tree_map_specs
    from repro.models.model import param_specs
    from repro.sharding import named_sharding
    import numpy as _np
    shard_shapes: set[tuple[int, ...]] = set()

    def acc(s):
        if _np.dtype(s.dtype).itemsize != 2 or len(s.shape) < 2:
            return
        shard = named_sharding(s.axes, s.shape, mesh, rules)\
            .shard_shape(s.shape) if mesh is not None else s.shape
        shard_shapes.add(tuple(shard))
        shard_shapes.add(tuple(s.shape))   # FSDP-gathered full-shape copies

    tree_map_specs(acc, param_specs(cfg))
    seen: set[tuple[int, ...]] = set()
    total = 0
    for m in _re.finditer(r"= f32\[([0-9,]+)\]", hlo_text):
        dims = tuple(int(x) for x in m.group(1).split(","))
        if dims in shard_shapes and dims not in seen:
            seen.add(dims)                 # buffers of one shape are reused
            total += 4 * math.prod(dims)
    return total


def lower_cell(arch, shape_name: str, *, multi_pod: bool = False,
               mesh="auto", cfg=None, grad_accum=None, rules=None):
    """Build mesh + specs and lower the cell's step function (no compile).
    ``mesh=None`` lowers unsharded (analysis mode); ``cfg`` may override the
    registry config (depth-reduced analysis lowering)."""
    cfg = cfg or get_config(arch)
    cell = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return None, "skip: pure full-attention arch at 500k (DESIGN.md)"
    if mesh == "auto":
        mesh = make_production_mesh(multi_pod=multi_pod)

    if cell.kind == "train":
        from repro.training.train_step import make_train_step
        rules = rules or (train_rules_for(cfg) if mesh is not None else {})
        accum = grad_accum or (default_grad_accum(cfg, cell, mesh, rules)
                               if mesh is not None else 1)
        specs = input_specs(cfg, shape_name, mesh, rules)
        step = make_train_step(cfg, grad_accum=accum)
        with sharding_ctx(mesh, rules):
            lowered = jax.jit(step, donate_argnums=(0,)).lower(
                specs["state"], specs["batch"])
        return (lowered, {"grad_accum": accum, "mesh": mesh,
                          "rules": rules}), None

    rules = rules or (serve_rules_for(cfg, shape_name)
                      if mesh is not None else {})
    specs = input_specs(cfg, shape_name, mesh, rules)
    from repro.models import model as M
    if cell.kind == "prefill":
        def step(params, batch, caches):
            return M.prefill(cfg, params, batch, caches)
        with sharding_ctx(mesh, rules):
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                specs["params"], specs["batch"], specs["caches"])
    else:
        def step(params, tokens, positions, caches):
            return M.decode_step(cfg, params, tokens, positions, caches)
        with sharding_ctx(mesh, rules):
            lowered = jax.jit(step, donate_argnums=(3,)).lower(
                specs["params"], specs["tokens"], specs["positions"],
                specs["caches"])
    return (lowered, {"grad_accum": 1, "mesh": mesh, "rules": rules}), None


def analysis_flops_bytes(arch: str, shape_name: str,
                         n_chips: int) -> tuple[float, float]:
    """Per-chip (FLOPs, HBM bytes) via unrolled depth-extrapolated lowering:
    lower the unsharded step at 1 and 2 scan groups (scans unrolled so
    HloCostAnalysis sees every layer) and extend linearly to full depth —
    exact, since scanned groups are identical.  Train cells lower with
    grad_accum=1 (identical total math).  See DESIGN.md / EXPERIMENTS.md
    methodology."""
    import dataclasses as dc
    from repro.tracemode import analysis_mode
    cfg = get_config(arch)
    pat, tail = len(cfg.block_pattern), len(cfg.tail_pattern)
    vals = {}
    for k in (1, 2):
        cfg_k = dc.replace(
            cfg, name=f"{cfg.name}@depth{k}", num_layers=pat * k + tail,
            encoder_layers=k if cfg.encoder_layers else 0)
        with analysis_mode():
            out, skip = lower_cell(arch, shape_name, mesh=None, cfg=cfg_k,
                                   grad_accum=1)
            assert not skip, skip
            lowered, _ = out
        vals[k] = cost_flops_bytes(lowered.cost_analysis())
    n = cfg.n_groups
    flops = vals[1][0] + (n - 1) * (vals[2][0] - vals[1][0])
    hbm = vals[1][1] + (n - 1) * (vals[2][1] - vals[1][1])
    return flops / n_chips, hbm / n_chips


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, tag: str = "", quant: bool = False,
             **lower_kwargs) -> dict[str, Any]:
    cell = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "ok": False}
    if tag:
        rec["tag"] = tag
    t0 = time.time()
    import contextlib
    from repro.models.layers import weight_quant
    qctx = weight_quant() if quant else contextlib.nullcontext()
    try:
        ctx_tok = qctx.__enter__()
        del ctx_tok
        out, skip = lower_cell(arch, shape_name, multi_pod=multi_pod,
                               **lower_kwargs)
        if skip:
            rec.update(skipped=skip, ok=True)
            return rec
        lowered, meta = out
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        cfg = get_config(arch)
        n_chips = math.prod(meta["mesh"].shape.values())
        # FLOPs/bytes: unrolled depth-extrapolated analysis lowering (XLA
        # counts while bodies once, so the scanned compiled module alone
        # undercounts by ~n_groups x grad_accum).
        flops, xla_bytes = analysis_flops_bytes(arch, shape_name, n_chips)
        hbm = hbm_traffic_model(cfg, cell, meta["mesh"], meta["rules"],
                                meta["grad_accum"])
        rec["xla_bytes_accessed"] = xla_bytes     # reference only (pre-fusion)
        hlo_text = compiled.as_text()
        coll = parse_collective_bytes(hlo_text)
        upcast = f32_weight_upcast_bytes(hlo_text, cfg, meta["mesh"],
                                         meta["rules"])
        rl = Roofline(flops=flops, hbm_bytes=hbm,
                      coll_bytes=float(coll["total_bytes"]),
                      model_flops=model_flops_per_chip(
                          cfg, cell, n_chips, meta["grad_accum"]))
        rec.update(ok=True, grad_accum=meta["grad_accum"],
                   memory=_memory_stats(compiled),
                   collectives=coll, roofline=rl.as_dict())
        peak = rec["memory"].get("peak_bytes")
        if peak is not None:
            upcast = min(upcast, rec["memory"].get("temp_bytes", 0))
            rec["memory"]["f32_weight_upcast_bytes"] = float(upcast)
            rec["memory"]["peak_tpu_estimate"] = peak - upcast
            peak = peak - upcast
        rec["fits_hbm"] = bool(peak is not None and peak <= HBM_PER_CHIP)
        if verbose:
            mem_gib = (peak or 0) / 2 ** 30
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK  "
                  f"peak={mem_gib:.2f} GiB(tpu-est)  bound={rl.bound}  "
                  f"compute={rl.compute_s*1e3:.2f}ms  "
                  f"memory={rl.memory_s*1e3:.2f}ms  "
                  f"coll={rl.collective_s*1e3:.2f}ms  "
                  f"useful={rl.useful_ratio:.2f}")
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
                  f"FAIL {rec['error']}")
    finally:
        qctx.__exit__(None, None, None)
        rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    args = ap.parse_args()

    done: set[tuple[str, str, str]] = set()
    if os.path.exists(args.out):                    # resume: skip OK cells
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape, args.multi_pod))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape, args.multi_pod))

    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        if (arch, shape, mesh_name) in done:
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: cached OK")
            continue
        rec = run_cell(arch, shape, multi_pod=mp)
        rec.pop("traceback", None) if rec.get("ok") else None
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
