"""Host-memory snapshot pool: warm-restart state that outlives containers.

An expired or suffix-evicted warm container used to discard its prefix KV,
so the next invocation of the same function paid full prefill.  Following
the serverless snapshot/restore fast path (TrEnv-X remote snapshot pools;
the vHive snapshot study), the host instead keeps a copied-out partition
per function profile in a *host-side* pool and restores it into a freshly
admitted partition — cheaper than prefill, dearer than a warm adopt.

The pool is exactly a Squeezy-style segregated region with bounded
allocation lifetime: every byte in it is immediately droppable metadata
(the authoritative state lives nowhere else), so under host pressure the
broker reclaims snapshot units FIRST — an LRU drop is O(1) bookkeeping
with zero migration and zero victim involvement — before ordering any VM
to shrink.  ``SqueezeRecord`` logs those drops; the absence of
``migrated_bytes``/``ReclaimOrder`` traffic while the pool can cover a
grant is the property the tests pin down.

Unit accounting: the pool is charged against the same host block budget as
the replicas, extending the broker's conservation invariant to

    free + sum(granted) + escrow + snapshot_units == budget

``SnapshotPool`` itself is pure metadata + payload storage; all unit flows
(free pool <-> snapshot charge) are orchestrated by ``HostMemoryBroker``
so the invariant has a single owner.

Content-addressed pages: at millions-of-users scale most function
profiles share prefix structure (system prompts, common templates), so
storing one opaque payload per profile charges the same bytes N times.
Following application-guided dedup (User-guided Page Merging) and the
restore-is-a-mapping observation of the vHive snapshot study, a snapshot
may instead be a **manifest** — an ordered list of page digests into a
host-wide ``PageStore`` that holds each unique page once with a
refcount.  A page's units are charged against the ledger once, on first
reference (owner = the first-referencing tenant), and credited back only
when its refcount hits zero; dropping the owner's last reference while
other tenants still reference the page *reattributes* the charge to a
surviving tenant instead of stranding it.  ``pages=None`` entries are
the exact legacy one-opaque-payload layout, bit-identical to the
pre-page pool.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, OrderedDict
from typing import Any, Callable, Iterable, Optional, Sequence


@dataclasses.dataclass
class Snapshot:
    """One persisted prefix-KV partition, keyed by function profile."""
    key: str                     # function profile name
    units: int                   # host blocks charged against the budget
    tokens: int = 0              # prefix tokens the payload carries
    nbytes: int = 0              # payload bytes (the copy-out cost basis)
    payload: Any = None          # host-side row caches (device_get'd tree)
    replica_id: str = ""         # writer (informational: pool is host-wide)
    created_at: float = 0.0
    last_used: float = 0.0       # LRU recency stamp
    restores: int = 0            # times copied back into a partition
    # cross-host migration (repro.cluster.fleet): the host this entry was
    # copied from, and the modeled inter-host transfer wall still owed.
    # The first restore pays it (claim_copy) and the entry becomes local.
    origin_host: str = ""
    copy_seconds: float = 0.0
    # owning tenant: the sub-budget this entry's charge counts against
    # (empty = the ledger's sole default tenant).  The broker's fairness
    # rule protects another tenant's entries from being squeezed below
    # that tenant's sub-budget.
    tenant: str = ""
    # sharded KV (devices > 1): one payload fragment per device shard.
    # ``None`` = unsharded entry (the devices=1 layout); a sharded entry
    # is restorable only when EVERY fragment is present — a half-captured
    # replica is as useless as a half-drained one, so eviction and
    # migration always move the whole entry atomically.
    fragments: Optional[tuple] = None
    # content-addressed manifest (``None`` = legacy opaque payload): the
    # ordered page digests whose concatenation is this entry's prefix KV.
    # The pages themselves (payload bytes, units, refcount, owner tenant)
    # live in the host-wide ``PageStore``; ``units`` stays the manifest's
    # REFERENCED total (sum of its pages' units) while the ledger charge
    # is refcounted over unique pages.
    pages: Optional[tuple] = None

    @property
    def restorable(self) -> bool:
        """All state present to copy back: a payload, and — for sharded
        entries — every per-device fragment."""
        return self.payload is not None and (
            self.fragments is None
            or all(f is not None for f in self.fragments))

    def claim_copy(self) -> float:
        """Pay the pending inter-host copy: returns the owed wall once
        (0.0 for local entries and on every later restore)."""
        owed, self.copy_seconds = self.copy_seconds, 0.0
        return owed


@dataclasses.dataclass
class SqueezeRecord:
    """One pressure-time snapshot reclaim: the broker dropped ``key`` to
    cover ``requester``'s grant — metadata-only, zero migration, and no
    ``ReclaimOrder`` reached any replica for these units.  For paged
    entries ``units`` is what the drop actually freed (unique pages whose
    refcount hit zero), not the manifest's referenced total."""
    requester: str
    key: str
    units: int
    nbytes: int
    at: float                    # broker-clock timestamp
    tenant: str = ""             # the dropped entry's OWNER tenant


@dataclasses.dataclass
class Page:
    """One unique content-addressed page in the host-wide store.  The
    ledger is charged ``units`` exactly once for it (owner = the first
    tenant to reference it); ``refs`` counts manifest references across
    every snapshot entry on the host, ``ref_tenants`` the same broken
    down per tenant (so owner handoff on deref is deterministic)."""
    digest: str
    units: int
    nbytes: int
    payload: Any
    refs: int = 0
    owner: str = ""                      # charged tenant ("" = default)
    ref_tenants: dict = dataclasses.field(default_factory=dict)


class PageStoreSim:
    """Refcount twin for planning walks: ``_evict_plan`` and
    ``squeezable_snapshot_units`` must predict exactly what a sequence of
    manifest derefs would free (and for which owner), without touching
    the real store.  Mirrors ``PageStore.deref`` arithmetic, including
    deterministic owner reattribution."""

    def __init__(self, store: "PageStore"):
        self._refs = {d: p.refs for d, p in store._pages.items()}
        self._units = {d: p.units for d, p in store._pages.items()}
        self._owner = {d: p.owner for d, p in store._pages.items()}
        self._ref_tenants = {d: dict(p.ref_tenants)
                             for d, p in store._pages.items()}

    def clone(self) -> "PageStoreSim":
        """Independent copy, so a walk can trial-deref an entry and only
        commit the advance when the fairness rule admits the drop."""
        c = object.__new__(PageStoreSim)
        c._refs = dict(self._refs)
        c._units = dict(self._units)
        c._owner = dict(self._owner)
        c._ref_tenants = {d: dict(rt)
                          for d, rt in self._ref_tenants.items()}
        return c

    def new_units(self, pages: Sequence[tuple]) -> int:
        """Units a manifest insert would newly charge under the current
        simulated state: each distinct absent digest counts once."""
        seen: set = set()
        total = 0
        for digest, units, _nb, _payload in pages:
            if digest not in self._refs and digest not in seen:
                seen.add(digest)
                total += units
        return total

    def deref_entry(self, snap: Snapshot) -> tuple[int, dict[str, int]]:
        """Simulate dropping ``snap``'s manifest: returns ``(units
        freed, per-tenant snapshot-account delta)`` — freed pages debit
        their owner, owner handoffs debit the old owner and credit the
        new one — and advances the simulated refcounts, so a later entry
        in the same walk sees the post-drop state."""
        if snap.pages is None:
            return snap.units, {snap.tenant: -snap.units}
        freed = 0
        delta: dict[str, int] = {}
        for digest in snap.pages:
            self._refs[digest] -= 1
            rt = self._ref_tenants[digest]
            rt[snap.tenant] -= 1
            if rt[snap.tenant] == 0:
                del rt[snap.tenant]
            if self._refs[digest] == 0:
                u, owner = self._units[digest], self._owner[digest]
                freed += u
                delta[owner] = delta.get(owner, 0) - u
                del self._refs[digest], self._units[digest]
                del self._owner[digest], self._ref_tenants[digest]
            elif self._owner[digest] == snap.tenant \
                    and snap.tenant not in rt:
                old, new = self._owner[digest], min(rt)
                self._owner[digest] = new
                u = self._units[digest]
                delta[old] = delta.get(old, 0) - u
                delta[new] = delta.get(new, 0) + u
        return freed, delta


class PageStore:
    """Host-wide content-addressed page store: each unique page held
    once, refcounted over every manifest that references it.  All unit
    flows (first-reference charge, zero-refcount credit, owner handoff)
    are orchestrated by ``HostMemoryBroker`` against the ledger; the
    store only reports which flow each ref/deref requires."""

    def __init__(self):
        self._pages: dict[str, Page] = {}
        self.dedup_hits = 0              # refs that found the page present

    # -------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self._pages)

    def __contains__(self, digest: str) -> bool:
        return digest in self._pages

    def get(self, digest: str) -> Optional[Page]:
        return self._pages.get(digest)

    @property
    def unique_units(self) -> int:
        return sum(p.units for p in self._pages.values())

    @property
    def unique_nbytes(self) -> int:
        return sum(p.nbytes for p in self._pages.values())

    def missing(self, digests: Iterable[str]) -> list[str]:
        """Distinct digests not present — what a migration must actually
        move to this host (order preserved, duplicates collapsed)."""
        out, seen = [], set()
        for d in digests:
            if d not in self._pages and d not in seen:
                seen.add(d)
                out.append(d)
        return out

    def simulate(self) -> PageStoreSim:
        return PageStoreSim(self)

    # ------------------------------------------------------------ refcounts
    def ref(self, digest: str, *, units: int, nbytes: int, payload: Any,
            tenant: str) -> bool:
        """Add one manifest reference.  Returns True when the page was
        newly created (the caller must ``snapshot_charge`` its units to
        ``tenant``, who becomes the owner); False for a dedup hit (no
        ledger flow — the page is already paid for)."""
        p = self._pages.get(digest)
        if p is None:
            assert units >= 0 and nbytes >= 0 and payload is not None
            self._pages[digest] = Page(digest, units, nbytes, payload,
                                       refs=1, owner=tenant,
                                       ref_tenants={tenant: 1})
            return True
        assert p.units == units and p.nbytes == nbytes, \
            f"digest collision on {digest!r}: ({p.units}u/{p.nbytes}B) " \
            f"vs ({units}u/{nbytes}B)"
        p.refs += 1
        p.ref_tenants[tenant] = p.ref_tenants.get(tenant, 0) + 1
        self.dedup_hits += 1
        return False

    def deref(self, digest: str, tenant: str
              ) -> tuple[str, int, str, str]:
        """Drop one manifest reference.  Returns the ledger flow the
        caller must apply, as ``(outcome, units, frm, to)``:

        * ``("freed", u, owner, "")`` — refcount hit zero, page removed;
          credit ``u`` back to ``owner``.
        * ``("reattributed", u, old, new)`` — the owner's last reference
          dropped but other tenants still hold the page; move the charge
          ``old`` -> ``new`` (deterministic: lexicographic min of the
          surviving referencing tenants).
        * ``("shared", 0, "", "")`` — page still referenced and owned; no
          flow."""
        p = self._pages[digest]
        assert p.refs > 0 and p.ref_tenants.get(tenant, 0) > 0, \
            f"{digest!r}: deref by non-referencing tenant {tenant!r}"
        p.refs -= 1
        p.ref_tenants[tenant] -= 1
        if p.ref_tenants[tenant] == 0:
            del p.ref_tenants[tenant]
        if p.refs == 0:
            del self._pages[digest]
            return ("freed", p.units, p.owner, "")
        if tenant == p.owner and p.owner not in p.ref_tenants:
            old, p.owner = p.owner, min(p.ref_tenants)
            return ("reattributed", p.units, old, p.owner)
        return ("shared", 0, "", "")

    # ---------------------------------------------------------- invariants
    def owner_units(self) -> dict[str, int]:
        """Unique units charged per owner tenant (the per-tenant snapshot
        account cross-check for paged entries)."""
        out: dict[str, int] = {}
        for p in self._pages.values():
            out[p.owner] = out.get(p.owner, 0) + p.units
        return out

    def check_invariants(self) -> None:
        for d, p in self._pages.items():
            assert p.digest == d
            assert p.refs > 0, f"zero-ref page {d!r} not removed"
            assert p.units >= 0 and p.nbytes >= 0
            assert p.payload is not None
            assert all(c > 0 for c in p.ref_tenants.values()), (d, p)
            assert sum(p.ref_tenants.values()) == p.refs, (d, p)
            assert p.owner in p.ref_tenants, \
                f"page {d!r} charge stranded on non-referencing " \
                f"owner {p.owner!r}"

    # -------------------------------------------------------------- report
    def report(self) -> dict[str, Any]:
        return {
            "pages": len(self._pages),
            "unique_units": self.unique_units,
            "unique_nbytes": self.unique_nbytes,
            "referenced_units": sum(p.units * p.refs
                                    for p in self._pages.values()),
            "dedup_hits": self.dedup_hits,
        }


class SnapshotPool:
    """LRU pool of per-profile snapshots.  One snapshot per key (a newer
    capture of the same function replaces the old one); eviction order is
    least-recently-used, where both ``insert`` and ``lookup`` refresh
    recency.  ``max_units`` caps the pool's total budget charge."""

    def __init__(self, max_units: Optional[int] = None):
        assert max_units is None or max_units > 0
        self.max_units = max_units
        self._by_key: "OrderedDict[str, Snapshot]" = OrderedDict()
        # host-wide content-addressed page store for manifest entries;
        # empty (and charge-free) while every entry is the legacy opaque
        # layout, so ``units`` stays bit-identical to the pre-page pool
        self.pages = PageStore()
        # --- counters (reports read these) ---
        self.inserts = 0
        self.replaced = 0
        self.evictions = 0           # LRU/squeeze drops (not replacements)
        self.hits = 0
        self.misses = 0

    # -------------------------------------------------------------- queries
    @property
    def units(self) -> int:
        """The pool's CHARGED units: legacy entries at face value plus
        each unique page once — the figure the ledger's snapshot account
        holds (a paged manifest's referenced total is ``snap.units``)."""
        return sum(s.units for s in self._by_key.values()
                   if s.pages is None) + self.pages.unique_units

    @property
    def referenced_units(self) -> int:
        """Pre-dedup total: every entry's manifest units at face value
        (== ``units`` when no entry is paged)."""
        return sum(s.units for s in self._by_key.values())

    def __len__(self) -> int:
        return len(self._by_key)

    def __contains__(self, key: str) -> bool:
        return key in self._by_key

    def keys(self):
        return list(self._by_key)

    def peek(self, key: str) -> Optional[Snapshot]:
        """Availability probe: no recency refresh, no hit/miss accounting
        (the router calls this per arrival)."""
        return self._by_key.get(key)

    def fits(self, units: int) -> bool:
        """Cap check only: could a ``units``-block snapshot ever fit,
        with every current entry evicted?  (Free-pool headroom is the
        broker's side of the ``snapshot_room`` answer.)"""
        return self.max_units is None or units <= self.max_units

    # ------------------------------------------------------------ mutation
    def lookup(self, key: str, now: float = 0.0) -> Optional[Snapshot]:
        """Restore-path fetch: refresh recency, count the hit.  The
        snapshot stays in the pool (one capture serves every later
        invocation of the profile until evicted)."""
        snap = self._by_key.get(key)
        if snap is None:
            self.misses += 1
            return None
        self.hits += 1
        snap.last_used = now
        snap.restores += 1
        self._by_key.move_to_end(key)
        return snap

    def insert(self, snap: Snapshot) -> None:
        """Store ``snap`` as the most recent entry.  The caller (broker)
        has already dropped any same-key predecessor and charged
        ``snap.units`` against the free pool.  A paged entry's pages are
        already ref'd into the store (so ``self.units`` counts them); the
        manifest itself adds no charge beyond its unique pages."""
        assert snap.key not in self._by_key, snap.key
        assert snap.units > 0, snap
        add = snap.units if snap.pages is None else 0
        assert self.max_units is None or self.units + add \
            <= self.max_units, "pool cap overflow: caller must evict first"
        self.inserts += 1
        self._by_key[snap.key] = snap

    def drop(self, key: str) -> int:
        """Remove ``key``; returns the units to credit back.  Used for
        same-key replacement (not counted as an eviction)."""
        snap = self._by_key.pop(key, None)
        return snap.units if snap is not None else 0

    def evict_lru(self, eligible: Optional[Callable[[Snapshot], bool]] = None
                  ) -> Optional[Snapshot]:
        """Drop the least-recently-used snapshot (squeeze/cap path).  With
        an ``eligible`` predicate, drop the least-recent entry the
        predicate admits — the broker passes its tenant-protection rule
        here, so protected entries are skipped, not reordered."""
        for key, snap in self._by_key.items():
            if eligible is None or eligible(snap):
                del self._by_key[key]
                self.evictions += 1
                return snap
        return None

    def evict(self, key: str) -> Optional[Snapshot]:
        """Drop a specific entry as an *eviction* (counted, unlike
        ``drop``): the broker's planned same-key/LRU eviction path."""
        snap = self._by_key.pop(key, None)
        if snap is not None:
            self.evictions += 1
        return snap

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        assert all(s.units > 0 for s in self._by_key.values())
        assert all(s.key == k for k, s in self._by_key.items())
        for s in self._by_key.values():
            if s.fragments is not None:
                assert len(s.fragments) >= 1 and \
                    s.units % len(s.fragments) == 0, \
                    f"{s.key}: {s.units} units over " \
                    f"{len(s.fragments)} fragments"
        # the store's refcounts are EXACTLY the live manifests' references
        # (per digest and per tenant), so no page outlives its manifests
        # and no manifest references an absent page
        refs: Counter = Counter()
        tenant_refs: Counter = Counter()
        for s in self._by_key.values():
            if s.pages is None:
                continue
            assert len(s.pages) >= 1, s.key
            total = 0
            for d in s.pages:
                p = self.pages.get(d)
                assert p is not None, \
                    f"{s.key}: manifest page {d!r} missing from store"
                total += p.units
                refs[d] += 1
                tenant_refs[(d, s.tenant)] += 1
            assert total == s.units, \
                f"{s.key}: manifest units {s.units} != page sum {total}"
        assert refs == Counter({d: self.pages.get(d).refs
                                for d in self.pages._pages}), \
            "page refcounts diverged from live manifests"
        for (d, t), n in tenant_refs.items():
            assert self.pages.get(d).ref_tenants.get(t, 0) == n, \
                f"page {d!r}: tenant {t!r} refcount diverged"
        self.pages.check_invariants()
        if self.max_units is not None:
            assert self.units <= self.max_units, \
                f"pool holds {self.units} units over cap {self.max_units}"

    # -------------------------------------------------------------- report
    def report(self) -> dict[str, Any]:
        return {
            "count": len(self._by_key),
            "units": self.units,
            "referenced_units": self.referenced_units,
            "max_units": self.max_units,
            "inserts": self.inserts,
            "replaced": self.replaced,
            "evictions": self.evictions,
            "hits": self.hits,
            "misses": self.misses,
            "keys": list(self._by_key),
            "pages": self.pages.report(),
        }
