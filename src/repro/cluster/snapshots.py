"""Host-memory snapshot pool: warm-restart state that outlives containers.

An expired or suffix-evicted warm container used to discard its prefix KV,
so the next invocation of the same function paid full prefill.  Following
the serverless snapshot/restore fast path (TrEnv-X remote snapshot pools;
the vHive snapshot study), the host instead keeps a copied-out partition
per function profile in a *host-side* pool and restores it into a freshly
admitted partition — cheaper than prefill, dearer than a warm adopt.

The pool is exactly a Squeezy-style segregated region with bounded
allocation lifetime: every byte in it is immediately droppable metadata
(the authoritative state lives nowhere else), so under host pressure the
broker reclaims snapshot units FIRST — an LRU drop is O(1) bookkeeping
with zero migration and zero victim involvement — before ordering any VM
to shrink.  ``SqueezeRecord`` logs those drops; the absence of
``migrated_bytes``/``ReclaimOrder`` traffic while the pool can cover a
grant is the property the tests pin down.

Unit accounting: the pool is charged against the same host block budget as
the replicas, extending the broker's conservation invariant to

    free + sum(granted) + escrow + snapshot_units == budget

``SnapshotPool`` itself is pure metadata + payload storage; all unit flows
(free pool <-> snapshot charge) are orchestrated by ``HostMemoryBroker``
so the invariant has a single owner.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Optional


@dataclasses.dataclass
class Snapshot:
    """One persisted prefix-KV partition, keyed by function profile."""
    key: str                     # function profile name
    units: int                   # host blocks charged against the budget
    tokens: int = 0              # prefix tokens the payload carries
    nbytes: int = 0              # payload bytes (the copy-out cost basis)
    payload: Any = None          # host-side row caches (device_get'd tree)
    replica_id: str = ""         # writer (informational: pool is host-wide)
    created_at: float = 0.0
    last_used: float = 0.0       # LRU recency stamp
    restores: int = 0            # times copied back into a partition
    # cross-host migration (repro.cluster.fleet): the host this entry was
    # copied from, and the modeled inter-host transfer wall still owed.
    # The first restore pays it (claim_copy) and the entry becomes local.
    origin_host: str = ""
    copy_seconds: float = 0.0
    # owning tenant: the sub-budget this entry's charge counts against
    # (empty = the ledger's sole default tenant).  The broker's fairness
    # rule protects another tenant's entries from being squeezed below
    # that tenant's sub-budget.
    tenant: str = ""
    # sharded KV (devices > 1): one payload fragment per device shard.
    # ``None`` = unsharded entry (the devices=1 layout); a sharded entry
    # is restorable only when EVERY fragment is present — a half-captured
    # replica is as useless as a half-drained one, so eviction and
    # migration always move the whole entry atomically.
    fragments: Optional[tuple] = None

    @property
    def restorable(self) -> bool:
        """All state present to copy back: a payload, and — for sharded
        entries — every per-device fragment."""
        return self.payload is not None and (
            self.fragments is None
            or all(f is not None for f in self.fragments))

    def claim_copy(self) -> float:
        """Pay the pending inter-host copy: returns the owed wall once
        (0.0 for local entries and on every later restore)."""
        owed, self.copy_seconds = self.copy_seconds, 0.0
        return owed


@dataclasses.dataclass
class SqueezeRecord:
    """One pressure-time snapshot reclaim: the broker dropped ``key`` to
    cover ``requester``'s grant — metadata-only, zero migration, and no
    ``ReclaimOrder`` reached any replica for these units."""
    requester: str
    key: str
    units: int
    nbytes: int
    at: float                    # broker-clock timestamp
    tenant: str = ""             # the dropped entry's OWNER tenant


class SnapshotPool:
    """LRU pool of per-profile snapshots.  One snapshot per key (a newer
    capture of the same function replaces the old one); eviction order is
    least-recently-used, where both ``insert`` and ``lookup`` refresh
    recency.  ``max_units`` caps the pool's total budget charge."""

    def __init__(self, max_units: Optional[int] = None):
        assert max_units is None or max_units > 0
        self.max_units = max_units
        self._by_key: "OrderedDict[str, Snapshot]" = OrderedDict()
        # --- counters (reports read these) ---
        self.inserts = 0
        self.replaced = 0
        self.evictions = 0           # LRU/squeeze drops (not replacements)
        self.hits = 0
        self.misses = 0

    # -------------------------------------------------------------- queries
    @property
    def units(self) -> int:
        return sum(s.units for s in self._by_key.values())

    def __len__(self) -> int:
        return len(self._by_key)

    def __contains__(self, key: str) -> bool:
        return key in self._by_key

    def keys(self):
        return list(self._by_key)

    def peek(self, key: str) -> Optional[Snapshot]:
        """Availability probe: no recency refresh, no hit/miss accounting
        (the router calls this per arrival)."""
        return self._by_key.get(key)

    def fits(self, units: int) -> bool:
        """Cap check only: could a ``units``-block snapshot ever fit,
        with every current entry evicted?  (Free-pool headroom is the
        broker's side of the ``snapshot_room`` answer.)"""
        return self.max_units is None or units <= self.max_units

    # ------------------------------------------------------------ mutation
    def lookup(self, key: str, now: float = 0.0) -> Optional[Snapshot]:
        """Restore-path fetch: refresh recency, count the hit.  The
        snapshot stays in the pool (one capture serves every later
        invocation of the profile until evicted)."""
        snap = self._by_key.get(key)
        if snap is None:
            self.misses += 1
            return None
        self.hits += 1
        snap.last_used = now
        snap.restores += 1
        self._by_key.move_to_end(key)
        return snap

    def insert(self, snap: Snapshot) -> None:
        """Store ``snap`` as the most recent entry.  The caller (broker)
        has already dropped any same-key predecessor and charged
        ``snap.units`` against the free pool."""
        assert snap.key not in self._by_key, snap.key
        assert snap.units > 0, snap
        assert self.max_units is None or self.units + snap.units \
            <= self.max_units, "pool cap overflow: caller must evict first"
        self.inserts += 1
        self._by_key[snap.key] = snap

    def drop(self, key: str) -> int:
        """Remove ``key``; returns the units to credit back.  Used for
        same-key replacement (not counted as an eviction)."""
        snap = self._by_key.pop(key, None)
        return snap.units if snap is not None else 0

    def evict_lru(self, eligible: Optional[Callable[[Snapshot], bool]] = None
                  ) -> Optional[Snapshot]:
        """Drop the least-recently-used snapshot (squeeze/cap path).  With
        an ``eligible`` predicate, drop the least-recent entry the
        predicate admits — the broker passes its tenant-protection rule
        here, so protected entries are skipped, not reordered."""
        for key, snap in self._by_key.items():
            if eligible is None or eligible(snap):
                del self._by_key[key]
                self.evictions += 1
                return snap
        return None

    def evict(self, key: str) -> Optional[Snapshot]:
        """Drop a specific entry as an *eviction* (counted, unlike
        ``drop``): the broker's planned same-key/LRU eviction path."""
        snap = self._by_key.pop(key, None)
        if snap is not None:
            self.evictions += 1
        return snap

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        assert all(s.units > 0 for s in self._by_key.values())
        assert all(s.key == k for k, s in self._by_key.items())
        for s in self._by_key.values():
            if s.fragments is not None:
                assert len(s.fragments) >= 1 and \
                    s.units % len(s.fragments) == 0, \
                    f"{s.key}: {s.units} units over " \
                    f"{len(s.fragments)} fragments"
        if self.max_units is not None:
            assert self.units <= self.max_units, \
                f"pool holds {self.units} units over cap {self.max_units}"

    # -------------------------------------------------------------- report
    def report(self) -> dict[str, Any]:
        return {
            "count": len(self._by_key),
            "units": self.units,
            "max_units": self.max_units,
            "inserts": self.inserts,
            "replaced": self.replaced,
            "evictions": self.evictions,
            "hits": self.hits,
            "misses": self.misses,
            "keys": list(self._by_key),
        }
