"""Host memory broker: the hypervisor/virtio-mem role of the paper, §2+§4.

One physical host runs N VM-sandboxed replicas (each a ``ServeEngine``) and
owns a fixed budget of memory units.  The broker is the host-side control
plane that arbitrates that budget; its verbs map onto the paper's terms:

  broker verb               paper mechanism
  -----------------------   --------------------------------------------
  ``register``              VM boot: the guest's initial memory plug
  ``request_grant``         virtio-mem **plug** request (guest asks the
                            hypervisor for more memory blocks); returns a
                            ``Grant`` — immediately-filled pool units plus
                            a *pending* remainder fed by reclaim orders
  ``release_units``         virtio-mem **unplug** completion (guest hands
                            reclaimed blocks back to the host)
  ``ReclaimOrder``          host memory pressure, asynchronously: the
                            hypervisor *asks* the idlest VMs to shrink
                            (Squeezy's sub-second reclaim is what makes
                            draining an order between decode steps cheap)
  ``fulfill_order``         a victim's partial unplug against its order;
                            the freed units land in the grant's escrow
  ``claim_grant``           the requester absorbs escrowed units at its
                            next tick (grant completion)
  unit (= one block)        a Linux 128 MiB memory block — here one
                            ``block_tokens`` slab of arena state

Grant / ReclaimOrder lifecycle (async mode)::

    requester                broker                    victim
    ---------                ------                    ------
    request_grant(want) ->   grant from free pool
                             issue ReclaimOrder(s) --> order_sink(order)
    <- Grant(granted,                                  ... decodes ...
             pending)                                  partial unplug
    ... decodes ...          fulfill_order(k)      <-- (tick boundary)
                             pending -= k
                             available += k  (escrow)
    claim_grant() ------->   available -> granted
    absorb rows              ...                       ... drains rest ...
                             (victim finishing naturally routes its
                              release_units into the open order instead
                              of the free pool — no double release; an
                              unfulfillable remainder is cancel_order'd)

In sync mode (``async_reclaim=False``, the pre-async behavior kept for the
benchmark contrast) ``request_grant`` runs the victims' reclaim callbacks
inline and reports the victim-side wall it serialized behind as
``Grant.stall_seconds`` — the requester-visible stall the async path
eliminates.

A unit is a *block* (``ArenaSpec.block_tokens`` worth of state), the finest
granularity both managers share; HotMem replicas convert partitions to
blocks at the boundary (1 partition = ``blocks_per_partition`` units).

Conservation invariant (the test suite's anchor): at all times
``free_units + sum(granted.values()) + escrow + snapshot_units ==
budget_units`` where ``escrow`` is the pending-delivery pool (units victims
already drained into open grants that their requesters have not claimed
yet) and ``snapshot_units`` is the host snapshot pool's charge (persisted
warm-restart state, see ``repro.cluster.snapshots``) — the host never
double-grants a unit and never leaks one, even mid-order.  All four
accounts live in a per-host ``BudgetLedger`` (``repro.cluster.ledger``):
every unit flow is a ledger verb and the invariant is checked by that one
code path, so the fleet layer (``repro.cluster.fleet``) can run N hosts
and assert per-host conservation after every fleet event — including
cross-host snapshot migrations — without re-deriving the law anywhere.

Sharded hosts (``topology=DeviceTopology(...)``, devices > 1): the ledger
keeps one account column per device and every balanced flow stripes over
the mesh; ``ReclaimOrder``s become **shard-coherent** — a victim's shards
drain in lockstep, per-shard fills sit in ``Grant.incoherent`` escrow
until every sibling shard catches up, and only coherent stripes are ever
claimable.  ``devices=1`` is bit-identical to the pre-topology broker.

Snapshot-squeeze-first reclaim rule: when a plug request outruns the free
pool, the broker first drops LRU snapshots (``_squeeze_snapshots`` —
metadata-only, zero migration, zero victim involvement) and only covers
the *remaining* deficit with reclaim orders (async) or inline steals
(sync).  While the pool can cover the grant, no ``ReclaimOrder`` reaches
any replica.

Pressure signal: ``pressure()`` = outstanding ordered-but-undrained units /
budget; ``open_order_units(rid)`` is the per-victim view the router's
power-of-two policy uses to avoid replicas that are mid-reclaim.

``AlwaysGrantBroker`` is the single-replica degenerate case: an unmetered
host that grants every request, so a lone ``ServeEngine`` behaves exactly
as it did before the broker existed.

The broker's clock is injectable (``clock=``/``set_clock``): standalone it
stamps with ``time.perf_counter``; under ``ClusterSim`` the sim passes its
deterministic virtual clock so ``StealRecord.wall_seconds`` and order
timestamps replay identically for a fixed (trace, seed).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

from repro.core.arena import ReclaimEvent
from repro.cluster.ledger import BudgetLedger
from repro.cluster.snapshots import Snapshot, SnapshotPool, SqueezeRecord
from repro.cluster.topology import DeviceTopology

# victim-side reclaim callback: (k_units) -> (units_reclaimed, event|None)
ReclaimFn = Callable[[int], tuple[int, Optional[ReclaimEvent]]]


@dataclasses.dataclass
class StealRecord:
    """One host-pressure reclaim: the broker shrank ``victim`` to feed
    ``requester`` (the paper's headline metric is how fast this is)."""
    requester: str
    victim: str
    units: int                   # blocks moved from victim to the free pool
    wall_seconds: float          # victim-side reclaim latency
    reclaimed_bytes: int
    migrated_bytes: int          # 0 for hotmem victims by construction
    mode: Optional[str] = None   # victim's manager mode
    natural: bool = False        # filled by the victim's own release, not
    #                              an explicit order drain (zero extra wall)


@dataclasses.dataclass
class ReclaimOrder:
    """An asynchronous shrink request from host to victim VM.  The victim
    drains it incrementally at its own tick boundaries (``fulfill_order``)
    or lets natural releases cover it; an unfulfillable remainder is
    canceled (``cancel_order``).

    Sharded victims (``shards > 1``: one KV shard per device of the host
    mesh) drain **shard-coherently**: the order tracks per-shard fill and
    cancel vectors, and only the *coherent* stripe — the minimum fill
    across shards, times ``shards`` — ever becomes claimable by the
    requesting grant.  A fill on one device may not unfence another
    device's warm suffix: those units sit in ``Grant.incoherent`` escrow
    until every sibling shard catches up (or the order closes and the
    stranded remainder is unwound back to the free pool)."""
    order_id: int
    victim: str
    requester: str
    units: int                   # blocks ordered (all shards together)
    filled: int = 0              # blocks drained so far
    canceled: int = 0            # blocks the victim could not supply
    issued_at: float = 0.0       # broker-clock timestamp
    closed_at: Optional[float] = None
    shards: int = 1              # device shards draining in lockstep
    filled_by_shard: list[int] = dataclasses.field(default_factory=list)
    canceled_by_shard: list[int] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        assert self.units % self.shards == 0, \
            f"order of {self.units} units does not stripe over " \
            f"{self.shards} shards"
        if not self.filled_by_shard:
            self.filled_by_shard = [0] * self.shards
        if not self.canceled_by_shard:
            self.canceled_by_shard = [0] * self.shards

    @property
    def per_shard(self) -> int:
        """Each shard's slice of the order."""
        return self.units // self.shards

    def shard_remaining(self, shard: int) -> int:
        return self.per_shard - self.filled_by_shard[shard] \
            - self.canceled_by_shard[shard]

    @property
    def coherent_filled(self) -> int:
        """Blocks filled on EVERY shard — the only part of the drain the
        requester may claim (the minimum stripe, times shards)."""
        return min(self.filled_by_shard) * self.shards

    @property
    def remaining(self) -> int:
        return self.units - self.filled - self.canceled

    @property
    def open(self) -> bool:
        return self.remaining > 0


@dataclasses.dataclass
class Grant:
    """The result of one plug request.  ``granted`` units are usable
    immediately; ``pending`` arrive later as reclaim orders drain, landing
    in ``available`` (escrow) until the requester ``claim_grant``s them."""
    replica_id: str
    requested: int
    granted: int = 0             # filled from the free pool, already owned
    pending: int = 0             # owed by open reclaim orders
    available: int = 0           # escrow: drained coherently, awaiting claim
    claimed: int = 0             # escrow already delivered
    incoherent: int = 0          # escrow drained on SOME shards of an order
    #                              only — unclaimable until the sibling
    #                              shards catch up (sharded victims)
    order_ids: list[int] = dataclasses.field(default_factory=list)
    stall_seconds: float = 0.0   # sync mode: victim reclaim wall the
    #                              requester serialized behind (async: 0)

    @property
    def done(self) -> bool:
        """No more units will arrive (escrow may still await a claim)."""
        return self.pending == 0

    @property
    def fulfilled(self) -> int:
        return self.granted + self.claimed + self.available \
            + self.incoherent + self.pending


class MemoryBroker:
    """Interface: what a replica needs from its host."""

    def register(self, replica_id: str, initial_units: int, *,
                 reclaim: Optional[ReclaimFn] = None,
                 load: Optional[Callable[[], int]] = None,
                 mode: Optional[str] = None,
                 order_sink: Optional[Callable[[ReclaimOrder], None]] = None,
                 ) -> None:
        raise NotImplementedError

    def request_units(self, replica_id: str, want: int) -> int:
        raise NotImplementedError

    def request_grant(self, replica_id: str, want: int) -> Grant:
        """Grant protocol: brokers without async reclaim wrap the legacy
        blocking call in an already-complete ``Grant``."""
        return Grant(replica_id=replica_id, requested=max(want, 0),
                     granted=self.request_units(replica_id, want))

    def release_units(self, replica_id: str, units: int) -> None:
        raise NotImplementedError

    def claim_grant(self, grant: Grant) -> int:
        """Deliver escrowed units to the requester; 0 for sync brokers."""
        return 0

    def abandon_grant(self, grant: Grant) -> int:
        """Cancel a pending grant's unfilled remainder; no-op for brokers
        without the async order plane."""
        return 0

    # Snapshot pool API: brokers without a host snapshot pool decline every
    # offer and miss every lookup, so engines wired to them behave exactly
    # as before the pool existed (warm state is simply discarded).
    def snapshot_room(self, key: str, units: int, *, tenant: str = "",
                      replica_id: str = "", pages: Any = None) -> bool:
        return False

    def snapshot_put(self, key: str, *, units: int, payload: Any = None,
                     tokens: int = 0, nbytes: int = 0,
                     replica_id: str = "", origin_host: str = "",
                     copy_seconds: float = 0.0, tenant: str = "",
                     fragments: Any = None, pages: Any = None) -> bool:
        return False

    def snapshot_lookup(self, key: str) -> Optional[Snapshot]:
        return None

    def snapshot_available(self, key: str) -> bool:
        return False

    def snapshot_restorable(self, key: str) -> bool:
        return False

    def snapshot_units(self) -> int:
        return 0

    def snapshot_page_specs(self, key: str) -> Optional[list]:
        """Page specs ``(digest, units, nbytes, payload)`` of a paged
        entry's manifest, in manifest order (``None`` for absent or
        legacy opaque entries)."""
        return None

    def missing_pages(self, digests: Any) -> list:
        """Distinct digests the host's page store does NOT hold — what a
        migration must actually move here.  Poolless brokers lack every
        page."""
        out, seen = [], set()
        for d in digests:
            if d not in seen:
                seen.add(d)
                out.append(d)
        return out


class AlwaysGrantBroker(MemoryBroker):
    """Unmetered host: every plug request is granted in full.  Used by a
    standalone ``ServeEngine`` so single-replica behavior is unchanged."""

    def register(self, replica_id: str, initial_units: int, **_: Any) -> None:
        pass

    def request_units(self, replica_id: str, want: int) -> int:
        return max(want, 0)

    def release_units(self, replica_id: str, units: int) -> None:
        pass


class HostMemoryBroker(MemoryBroker):
    """Fixed-budget host arbiter: grant on demand, reclaim-from-idlest
    under pressure — synchronously (legacy) or via async reclaim orders."""

    def __init__(self, budget_units: Optional[int] = None, *,
                 async_reclaim: bool = False,
                 clock: Optional[Callable[[], float]] = None,
                 snapshot_pool_units: Optional[int] = None,
                 tenants: Optional[dict[str, int]] = None,
                 topology: Optional[DeviceTopology] = None):
        # all unit accounts (free / granted / escrow / snapshot charge)
        # live in the ledger; the broker only orchestrates flows.
        # ``tenants``: optional per-tenant sub-budget split (must sum to
        # the budget) — enables the fairness rule in _squeeze_snapshots.
        # ``topology``: the device mesh this host exposes; omitted =
        # single flat pool of ``budget_units`` (the exact legacy broker)
        self.ledger = BudgetLedger(budget_units, tenants=tenants,
                                   topology=topology)
        self.topology = self.ledger.topology
        self.async_reclaim = async_reclaim
        self._clock = clock if clock is not None else time.perf_counter
        # host snapshot pool (None = disabled): warm-restart state charged
        # against this same budget, squeezed FIRST under pressure
        self.snapshots: Optional[SnapshotPool] = None
        if snapshot_pool_units is not None:
            assert snapshot_pool_units <= self.ledger.budget_units
            self.snapshots = SnapshotPool(max_units=snapshot_pool_units)
        self.squeeze_log: list[SqueezeRecord] = []
        self._inline_reclaim = False     # sync steal in flight: pool fenced
        self._reclaim: dict[str, ReclaimFn] = {}
        self._load: dict[str, Callable[[], int]] = {}
        self._mode: dict[str, Optional[str]] = {}
        self._order_sink: dict[str, Callable[[ReclaimOrder], None]] = {}
        self.orders: dict[int, ReclaimOrder] = {}
        self._victim_orders: dict[str, list[int]] = {}   # open orders only
        self._order_grant: dict[int, Grant] = {}
        self.grants: list[Grant] = []                    # open grants
        self._next_order = 0
        self.steal_log: list[StealRecord] = []
        self.grant_calls = 0
        self.denied_units = 0        # requested-but-ungranted (pressure)
        self.request_stalls: list[float] = []   # per pressured request: the
        #                                         requester-visible stall

    # ledger views: the broker's public unit counters ARE the ledger's
    # (one owner for the conservation law; these stay readable as before)
    @property
    def budget_units(self) -> int:
        return self.ledger.budget_units

    @property
    def free_units(self) -> int:
        return self.ledger.free_units

    @property
    def granted(self) -> dict[str, int]:
        return self.ledger.granted

    def set_clock(self, clock: Callable[[], float]) -> None:
        """Inject a (virtual) clock; ``ClusterSim`` passes its deterministic
        timebase so steal records replay identically."""
        self._clock = clock

    # ----------------------------------------------------------- lifecycle
    def register(self, replica_id: str, initial_units: int, *,
                 reclaim: Optional[ReclaimFn] = None,
                 load: Optional[Callable[[], int]] = None,
                 mode: Optional[str] = None,
                 order_sink: Optional[Callable[[ReclaimOrder], None]] = None,
                 tenant: Optional[str] = None,
                 shards: Optional[int] = None) -> None:
        """VM boot: carve the replica's initial plug out of the free pool
        (squeezing snapshots first if the pool holds the needed slack —
        a booting VM outranks cached warm-restart state).  ``tenant``
        binds the replica to its sub-budget (required on multi-tenant
        hosts; the squeeze respects other tenants' sub-budgets).
        ``shards`` is the replica's shard spec: replicas span the full
        mesh (one KV shard per device), so it must equal the topology's
        device count — the broker validates rather than infers so a
        mis-sharded replica fails at boot, not mid-reclaim."""
        assert replica_id not in self.granted, replica_id
        n_dev = self.topology.n_devices
        assert shards is None or shards == n_dev, \
            f"{replica_id} declares {shards} shards on a {n_dev}-device " \
            f"mesh: replicas span the full mesh"
        self.topology.assert_balanced(initial_units,
                                      f"boot plug for {replica_id}")
        tenant = self.ledger.resolve_tenant(tenant)
        if initial_units > self.free_units:
            self._squeeze_snapshots(initial_units - self.free_units,
                                    requester=replica_id, tenant=tenant)
        assert initial_units <= self.free_units, \
            f"host budget exhausted registering {replica_id}: " \
            f"need {initial_units}, free {self.free_units}"
        self.ledger.carve(replica_id, initial_units, tenant=tenant)
        if reclaim is not None:
            self._reclaim[replica_id] = reclaim
        if load is not None:
            self._load[replica_id] = load
        if order_sink is not None:
            self._order_sink[replica_id] = order_sink
        self._mode[replica_id] = mode

    def deregister(self, replica_id: str) -> int:
        """VM teardown (host retirement): settle every open flow the
        replica participates in, return its whole holding to the free
        pool, and forget its account.  Victim-side: open orders against
        it are canceled (their requesters see the denial and may
        re-request elsewhere).  Requester-side: its grants' unfilled
        remainders are abandoned and already-coherent escrow is claimed
        into the holding before the release — so nothing strands in
        escrow and the ledger law holds through the teardown.  Returns
        units returned to the pool."""
        assert replica_id in self.granted, replica_id
        for oid in list(self._victim_orders.get(replica_id, ())):
            self.cancel_order(oid)
        for g in [g for g in self.grants if g.replica_id == replica_id]:
            self.abandon_grant(g)       # closes orders, unwinds incoherent
            self.claim_grant(g)         # coherent escrow -> holding
        units = self.granted[replica_id]
        if units > 0:
            self.ledger.release(replica_id, units)
        self.ledger.forget(replica_id)
        self._victim_orders.pop(replica_id, None)
        self._reclaim.pop(replica_id, None)
        self._load.pop(replica_id, None)
        self._order_sink.pop(replica_id, None)
        self._mode.pop(replica_id, None)
        return units

    # --------------------------------------------------------- plug/unplug
    def request_units(self, replica_id: str, want: int) -> int:
        """Legacy blocking plug: grant up to ``want`` units now.  A legacy
        caller cannot claim async fills, so any orders the request issued
        are canceled immediately — otherwise their proceeds would strand
        in escrow forever, silently shrinking the usable budget."""
        g = self.request_grant(replica_id, want)
        for oid in list(g.order_ids):
            self.cancel_order(oid)
        return g.granted

    def request_grant(self, replica_id: str, want: int) -> Grant:
        """virtio-mem plug: fill from the free pool immediately; cover any
        deficit by squeezing the snapshot pool (metadata-only, no victim
        disturbed), then by reclaim — inline (sync) or via orders
        (async)."""
        assert replica_id in self.granted, replica_id
        g = Grant(replica_id=replica_id, requested=max(want, 0))
        if want <= 0:
            return g
        # plug requests stripe over the replica's shards, so they must be
        # balanced over the mesh (trivially true on a 1-device topology)
        self.topology.assert_balanced(want, f"plug request by {replica_id}")
        self.grant_calls += 1
        g.granted = self.ledger.take_free(replica_id, want)
        deficit = want - g.granted
        if deficit <= 0:
            return g
        # snapshot-squeeze-first: cached warm-restart state is the host's
        # bounded-lifetime region — drop it before disturbing any replica
        if self._squeeze_snapshots(deficit, requester=replica_id):
            take = self.ledger.take_free(replica_id, deficit)
            g.granted += take
            deficit -= take
        if deficit <= 0:
            return g        # covered without a victim: like a free-pool
            #                 fill, it leaves no stall sample (the stall
            #                 series tracks requests that engaged reclaim)
        if self.async_reclaim:
            issued = self._issue_orders(replica_id, deficit, g)
            g.pending = issued
            self.denied_units += deficit - issued
            if g.pending:
                self.grants.append(g)
            self.request_stalls.append(0.0)     # requester never blocks
        else:
            stall = self._reclaim_from_idlest(replica_id, deficit)
            g.stall_seconds = stall
            self.request_stalls.append(stall)
            take2 = self.ledger.take_free(replica_id, deficit)
            g.granted += take2
            self.denied_units += deficit - take2
        return g

    def release_units(self, replica_id: str, units: int) -> None:
        """virtio-mem unplug completion.  A victim with open reclaim orders
        routes its released units into them first (a victim finishing
        naturally *is* the reclaim — crediting the free pool too would
        double-release); only the excess reaches the host pool."""
        if units <= 0:
            return
        assert self.granted.get(replica_id, 0) >= units, \
            f"{replica_id} returning {units} units it was never granted"
        for oid in list(self._victim_orders.get(replica_id, ())):
            if units <= 0:
                break
            o = self.orders[oid]
            # a natural release is balanced over the victim's shards, so
            # it may only cover the order's balanced capacity (the
            # scarcest shard bounds the stripe) — shards == 1 reduces to
            # the plain ``min(units, o.remaining)``
            k = min(units,
                    min(o.shard_remaining(d) for d in range(o.shards))
                    * o.shards)
            k -= k % o.shards
            if k > 0:
                self._apply_fill(o, k, wall=0.0, ev=None, natural=True)
                units -= k
        if units > 0:
            self.ledger.release(replica_id, units)

    # ----------------------------------------------------- snapshot pool
    def _snap_tenant(self, tenant: str, replica_id: str) -> str:
        """Resolve the owning tenant of a snapshot operation: an explicit
        ``tenant`` wins, else the writing replica's tenant, else the
        ledger's sole default tenant (asserts on ambiguity)."""
        if tenant:
            return self.ledger.resolve_tenant(tenant)
        if replica_id in self.ledger.tenant_of:
            return self.ledger.tenant_of[replica_id]
        return self.ledger.resolve_tenant(None)

    def _entry_delta(self, snap: Snapshot, sim=None
                     ) -> tuple[int, dict[str, int]]:
        """What dropping ``snap`` right now would do to the ledger:
        ``(units freed, per-tenant snapshot-account delta)``.  A legacy
        opaque entry frees its face value on its owner; a paged manifest
        frees only pages whose refcount hits zero and hands still-shared
        pages' charge to a surviving tenant (``sim`` carries the walk
        state for multi-drop planning; default is the live store)."""
        if snap.pages is None:
            owner = snap.tenant or self.ledger.resolve_tenant(None)
            return snap.units, {owner: -snap.units}
        if sim is None:
            sim = self.snapshots.pages.simulate()
        return sim.deref_entry(snap)

    def _release_entry_charge(self, snap: Snapshot) -> int:
        """Return an evicted/dropped entry's charge to the free pool: a
        legacy credit on its owner, or per-page deref flows for a
        manifest — freed pages credit their owners, still-shared pages'
        charge is reattributed to a surviving tenant (never stranded).
        Returns units actually freed."""
        if snap.pages is None:
            self.ledger.snapshot_credit(snap.units, snap.tenant or None)
            return snap.units
        store = self.snapshots.pages
        freed = 0
        for digest in snap.pages:
            outcome, u, frm, to = store.deref(digest, snap.tenant)
            if outcome == "freed":
                self.ledger.snapshot_credit(u, frm or None)
                freed += u
            elif outcome == "reattributed":
                self.ledger.snapshot_reattribute(u, frm or None,
                                                 to or None)
        return freed

    def _squeeze_eligible(self, tenant: str
                          ) -> Callable[[Snapshot], bool]:
        """The fairness rule: ``tenant``'s pressure may drop its OWN
        entries freely, but another tenant's entry only while that owner
        stays at or above its sub-budget afterwards — one tenant's grant
        can never squeeze another tenant's snapshots past its
        sub-budget.  For paged entries the rule is evaluated over the
        drop's real per-tenant deltas (unique pages freed, owner
        handoffs), not the manifest's referenced total."""
        led = self.ledger
        def ok(snap: Snapshot) -> bool:
            _, delta = self._entry_delta(snap)
            for owner, du in delta.items():
                if du >= 0 or owner == tenant:
                    continue
                if led.tenant_usage(owner) + du < led.sub_budgets[owner]:
                    return False
            return True
        return ok

    @staticmethod
    def _check_pages(pages, units: int, topology) -> tuple:
        """Normalize and validate a page-spec list ``(digest, units,
        nbytes, payload)``: manifest units must equal the page sum and
        every page's units must stripe balanced over the mesh (so any
        subset of pages charges/credits balanced)."""
        pages = tuple((d, int(u), int(nb), pl) for d, u, nb, pl in pages)
        assert len(pages) >= 1, "empty page manifest"
        assert units == sum(u for _, u, _, _ in pages), \
            f"manifest units {units} != page sum " \
            f"{sum(u for _, u, _, _ in pages)}"
        for d, u, _nb, pl in pages:
            assert u >= 0, (d, u)
            assert pl is not None, f"page {d!r} without payload"
            topology.assert_balanced(u, f"page {d!r}")
        return pages

    def _evict_plan(self, key: str, units: int, tenant: str,
                    pages: Optional[tuple] = None
                    ) -> Optional[list[str]]:
        """Exact eviction plan for inserting a ``units``-block snapshot
        under ``key``: the ordered entry keys to drop (same-key
        predecessor first, then LRU order, skipping tenant-protected
        entries) so the insert fits both the free pool and the pool cap —
        or ``None`` when no eligible plan exists.  ``snapshot_room`` asks
        whether a plan exists; ``snapshot_put`` executes the same plan, so
        the two can never disagree.

        With ``pages`` the arithmetic runs over *unique* pages: the
        incoming charge is only what the store doesn't already hold (a
        fully-shared manifest charges nothing), evicting a manifest frees
        only pages whose refcount would hit zero, and both are tracked on
        one refcount simulation so eviction/recharge interactions (an
        evicted sharer freeing a page the incoming manifest then re-pays)
        are priced exactly as execution will replay them."""
        pool = self.snapshots
        if pool is None or units <= 0 or self._inline_reclaim:
            return None
        # one refcount simulation carries the whole walk, so sequential
        # deref interactions (entry A's drop making entry B's pages
        # unique) are priced exactly as execution will replay them
        sim = pool.pages.simulate()

        def charge_now() -> int:
            return units if pages is None else sim.new_units(pages)

        # cap feasibility: the floor is the charge with everything else
        # evicted — for a manifest, its distinct pages' units
        if pages is None:
            floor = units
        else:
            seen: set = set()
            floor = 0
            for d, u, _nb, _pl in pages:
                if d not in seen:
                    seen.add(d)
                    floor += u
        if not pool.fits(floor):
            return None
        ok = self._squeeze_eligible(tenant)
        plan: list[str] = []
        freed = 0
        same = pool.peek(key)
        if same is not None:
            if not ok(same):
                return None     # cannot replace a protected entry
            plan.append(key)
            f, _ = self._entry_delta(same, sim=sim)
            freed += f

        def fits_now() -> bool:
            # a sharded snapshot charges one fragment per device, so the
            # headroom that matters is the BALANCED free pool (scarcest
            # device × devices) — identical to ``free_units`` at devices=1
            charge = charge_now()
            return charge <= self.ledger.balanced_free() + freed and (
                pool.max_units is None
                or pool.units - freed + charge <= pool.max_units)

        if fits_now():
            return plan
        for k in pool.keys():               # LRU -> MRU order
            if k == key:
                continue                    # already planned (replacement)
            snap = pool.peek(k)
            if not ok(snap):
                continue                    # protected: skip, not reorder
            plan.append(k)
            f, _ = self._entry_delta(snap, sim=sim)
            freed += f
            if fits_now():
                return plan
        return None

    def snapshot_room(self, key: str, units: int, *, tenant: str = "",
                      replica_id: str = "", pages: Any = None) -> bool:
        """Would a ``units``-block snapshot for ``key`` fit right now?  A
        same-key predecessor's charge and every *squeeze-eligible* entry
        count as reclaimable headroom (another tenant's entries only down
        to its sub-budget); insertion never creates pressure (it only
        spends free units), so the answer is also the engine's gate for
        paying the copy-out at all.  With ``pages`` the probe prices only
        the UNIQUE pages the store lacks — a fully-shared manifest always
        has room.  Declines while a sync inline steal is in flight:
        mid-steal free units belong to the open grant (see
        ``_reclaim_from_idlest``)."""
        if self.snapshots is None:
            return False
        t = self._snap_tenant(tenant, replica_id)
        if pages is not None:
            pages = self._check_pages(pages, units, self.topology)
        return self._evict_plan(key, units, t, pages=pages) is not None

    def snapshot_put(self, key: str, *, units: int, payload: Any = None,
                     tokens: int = 0, nbytes: int = 0,
                     replica_id: str = "", origin_host: str = "",
                     copy_seconds: float = 0.0, tenant: str = "",
                     fragments: Any = None, pages: Any = None) -> bool:
        """Persist a copied-out partition into the pool, charging ``units``
        against the free pool on the owner tenant's account.  A same-key
        predecessor is replaced; squeeze-eligible LRU entries are evicted
        for cap/space; returns False (nothing changed) when the snapshot
        cannot fit.  ``origin_host``/``copy_seconds`` mark a cross-host
        migration (``repro.cluster.fleet``): the modeled inter-host copy
        wall is paid by the first restore that uses the entry, so a remote
        restore lands between a local restore and a cold prefill.
        ``fragments`` is the sharded-KV form: one payload fragment per
        device; the entry is restorable only when every fragment is
        present, and its charge stripes balanced over the mesh.

        ``pages`` makes the entry a content-addressed manifest: a list of
        ``(digest, units, nbytes, payload)`` page specs whose units sum
        to ``units``.  Each page is ref'd into the host-wide store; only
        pages the store lacks charge the ledger (owner = this entry's
        tenant), so N profiles sharing a prefix pay for it once."""
        if self.snapshots is None:
            return False
        if fragments is not None:
            fragments = tuple(fragments)
            assert units % len(fragments) == 0, (units, len(fragments))
        t = self._snap_tenant(tenant, replica_id)
        if pages is not None:
            pages = self._check_pages(pages, units, self.topology)
        plan = self._evict_plan(key, units, t, pages=pages)
        if plan is None:
            return False
        pool = self.snapshots
        for k in plan:
            if k == key:                    # same-key replacement
                snap = pool.peek(key)
                pool.drop(key)
                pool.replaced += 1
            else:
                snap = pool.evict(k)
            self._release_entry_charge(snap)
        now = self._clock()
        manifest = None
        if pages is None:
            self.ledger.snapshot_charge(units, t)
        else:
            new_units = 0
            for digest, u, nb, pl in pages:
                if pool.pages.ref(digest, units=u, nbytes=nb,
                                  payload=pl, tenant=t):
                    new_units += u
            if new_units:
                self.ledger.snapshot_charge(new_units, t)
            manifest = tuple(digest for digest, _u, _nb, _pl in pages)
        pool.insert(Snapshot(key=key, units=units, tokens=tokens,
                             nbytes=nbytes, payload=payload,
                             replica_id=replica_id, created_at=now,
                             last_used=now, origin_host=origin_host,
                             copy_seconds=copy_seconds, tenant=t,
                             fragments=fragments, pages=manifest))
        return True

    def snapshot_lookup(self, key: str) -> Optional[Snapshot]:
        """Restore-path fetch (refreshes LRU recency, counts hit/miss).
        The snapshot stays pooled: one capture serves every later
        invocation of the profile until evicted or replaced."""
        if self.snapshots is None:
            return None
        return self.snapshots.lookup(key, now=self._clock())

    def snapshot_available(self, key: str) -> bool:
        """Entry-presence probe: no recency refresh, no accounting."""
        return self.snapshots is not None \
            and self.snapshots.peek(key) is not None

    def snapshot_restorable(self, key: str) -> bool:
        """Restore-feasibility probe (router + engine admission): the
        entry must carry a payload to copy back — and, for sharded
        entries, EVERY per-device fragment (a half-captured replica is
        not a warm start).  Metadata-only entries (non-engine producers:
        broker-level tests, benchmarks) are *present* but not restorable
        — probing them here instead of via ``snapshot_lookup`` keeps
        them off the hit counter and out of the MRU slot, so dead
        entries stay first in squeeze order.  No recency refresh, no
        accounting."""
        if self.snapshots is None:
            return False
        snap = self.snapshots.peek(key)
        return snap is not None and snap.restorable

    def snapshot_drop(self, key: str) -> int:
        """Explicitly invalidate ``key`` (tests / staleness): its charge
        returns to the free pool (owner tenant's account).  Returns units
        freed — for a paged entry, only pages whose refcount hit zero."""
        if self.snapshots is None:
            return 0
        snap = self.snapshots.peek(key)
        if snap is None:
            return 0
        self.snapshots.drop(key)
        return self._release_entry_charge(snap)

    def snapshot_units(self) -> int:
        """The pool's current charge against the host budget (unique
        pages counted once)."""
        return self.snapshots.units if self.snapshots is not None else 0

    def snapshot_page_specs(self, key: str) -> Optional[list]:
        """Page specs ``(digest, units, nbytes, payload)`` of a paged
        entry's manifest, in manifest order — what a migration carries
        and a restore reassembles (``None`` for absent/legacy
        entries)."""
        if self.snapshots is None:
            return None
        snap = self.snapshots.peek(key)
        if snap is None or snap.pages is None:
            return None
        out = []
        for digest in snap.pages:
            p = self.snapshots.pages.get(digest)
            out.append((digest, p.units, p.nbytes, p.payload))
        return out

    def missing_pages(self, digests: Any) -> list:
        """Distinct digests this host's store does NOT hold — what a
        migration must actually move here (dedup-aware transfer
        sizing)."""
        if self.snapshots is None:
            return super().missing_pages(digests)
        return self.snapshots.pages.missing(digests)

    def squeezable_snapshot_units(self, tenant: Optional[str] = None) -> int:
        """Units that pressure under ``tenant`` could squeeze out of the
        pool RIGHT NOW — the placement-capacity probe (``FleetScheduler.
        capacity`` must never promise units ``register`` cannot deliver).

        Walks entries in LRU order simulating sequential drops exactly
        like ``_squeeze_snapshots``: the fairness predicate is
        re-evaluated against the post-drop owner usage, so two entries
        whose owner can only spare one are counted once, and paged
        entries count only pages whose refcount would hit zero under the
        walk's refcount simulation (shared pages free nothing until
        their last manifest drops).  ``tenant=None`` resolves to the
        sole tenant on a single-tenant ledger; on a multi-tenant ledger
        it is the *anonymous* probe — every entry is treated as another
        tenant's (the conservative floor: a real squeeze can only free
        more)."""
        if self.snapshots is None:
            return 0
        led = self.ledger
        if tenant or len(led.sub_budgets) == 1:
            tenant = led.resolve_tenant(tenant)
        usage: dict[str, int] = {}
        freed = 0
        sim = self.snapshots.pages.simulate()
        for key in self.snapshots.keys():          # LRU -> MRU
            snap = self.snapshots.peek(key)
            trial = sim.clone()
            f, delta = self._entry_delta(snap, sim=trial)
            if any(owner != tenant and du < 0
                   and usage.get(owner, led.tenant_usage(owner)) + du
                   < led.sub_budgets[owner]
                   for owner, du in delta.items()):
                continue                           # protected: skipped
            sim = trial
            for owner, du in delta.items():
                if owner != tenant:
                    usage[owner] = usage.get(
                        owner, led.tenant_usage(owner)) + du
            freed += f
        return freed

    def _squeeze_snapshots(self, deficit: int, *, requester: str,
                           tenant: Optional[str] = None) -> int:
        """The squeeze-first reclaim rule: drop LRU snapshots until
        ``deficit`` is covered or no eligible entry remains.  Metadata-only
        — zero bytes migrate, no replica is ordered to shrink, the freed
        units land in the free pool immediately.  Eligibility is the
        tenant fairness rule (``_squeeze_eligible``): the requesting
        tenant drops its own entries freely but can take another tenant's
        only down to that tenant's sub-budget.  A paged entry frees only
        pages whose refcount hits zero (its ``SqueezeRecord`` logs that
        figure, possibly 0 for a fully-shared manifest).  Returns units
        freed."""
        if self.snapshots is None or deficit <= 0:
            return 0
        if tenant is None:
            tenant = self._snap_tenant("", requester)
        ok = self._squeeze_eligible(tenant)
        freed = 0
        now = self._clock()
        while freed < deficit:
            snap = self.snapshots.evict_lru(eligible=ok)
            if snap is None:
                break
            # credit per entry on its OWNER's account so the protection
            # predicate sees up-to-date tenant usage for the next pick
            f = self._release_entry_charge(snap)
            freed += f
            self.squeeze_log.append(SqueezeRecord(
                requester=requester, key=snap.key, units=f,
                nbytes=snap.nbytes, at=now,
                tenant=snap.tenant or self.ledger.resolve_tenant(None)))
        return freed

    # --------------------------------------------------- async order plane
    def _issue_orders(self, requester: str, deficit: int, grant: Grant
                      ) -> int:
        """Spread ``deficit`` across reclaim orders to the idlest victims
        (fewest in-flight invocations), capped by what each victim holds
        beyond units already ordered from it.  On a multi-device mesh
        each order stripes over the victim's shards, so per-victim
        amounts are floored to the shard count (a 1-device mesh floors
        nothing)."""
        n_dev = self.topology.n_devices
        victims = sorted(
            (r for r in self.granted
             if r != requester and r in self._order_sink),
            key=lambda r: (self._load[r]() if r in self._load else 0, r))
        issued = 0
        now = self._clock()
        for v in victims:
            if deficit <= 0:
                break
            cap = self.granted[v] - self.open_order_units(v)
            cap -= cap % n_dev
            k = min(deficit, cap)
            if k <= 0:
                continue
            order = ReclaimOrder(order_id=self._next_order, victim=v,
                                 requester=requester, units=k,
                                 issued_at=now, shards=n_dev)
            self._next_order += 1
            self.orders[order.order_id] = order
            self._victim_orders.setdefault(v, []).append(order.order_id)
            self._order_grant[order.order_id] = grant
            grant.order_ids.append(order.order_id)
            deficit -= k
            issued += k
            self._order_sink[v](order)
        return issued

    def fulfill_order(self, order_id: int, units: int,
                      ev: Optional[ReclaimEvent] = None,
                      shard: Optional[int] = None) -> int:
        """Victim-side partial drain: move up to ``units`` blocks from the
        victim's grant into the order's escrow.  Returns blocks accepted
        (the victim releases any unplugged excess normally).

        ``shard=d`` drains one device shard of the order (sharded
        victims call this once per device as each shard's suffix
        unfences); ``shard=None`` is a balanced drain over every shard
        at once — on a 1-shard order that is exactly the legacy call."""
        o = self.orders[order_id]
        if shard is not None:
            assert 0 <= shard < o.shards, (shard, o.shards)
            k = min(units, o.shard_remaining(shard),
                    self.ledger.granted_dev(o.victim)[shard])
            if k <= 0:
                return 0
            self._apply_fill(o, k, wall=ev.wall_seconds if ev is not None
                             else 0.0, ev=ev, natural=False, shard=shard)
            return k
        k = min(units, o.remaining, self.granted[o.victim])
        if o.shards > 1:
            # balanced drain: the scarcest shard bounds the stripe, both
            # order-side (shard_remaining) and victim-side (granted_dev)
            k = min(k,
                    min(o.shard_remaining(d) for d in range(o.shards))
                    * o.shards,
                    min(self.ledger.granted_dev(o.victim)) * o.shards)
            k -= k % o.shards
        if k <= 0:
            return 0
        self._apply_fill(o, k, wall=ev.wall_seconds if ev is not None
                         else 0.0, ev=ev, natural=False)
        return k

    def _apply_fill(self, o: ReclaimOrder, k: int, *, wall: float,
                    ev: Optional[ReclaimEvent], natural: bool,
                    shard: Optional[int] = None) -> None:
        """Move ``k`` drained blocks into the order's escrow and update
        the grant's coherence split: only the stripe filled on EVERY
        shard becomes ``available`` (claimable); the rest waits in
        ``incoherent`` until sibling shards catch up.  1-shard orders
        are always coherent, so the split degenerates to the legacy
        ``available += k``."""
        g = self._order_grant[o.order_id]
        old_coherent = o.coherent_filled
        if shard is None:
            self.ledger.escrow_fill(o.victim, k, requester=o.requester)
            per = k // o.shards
            for d in range(o.shards):
                o.filled_by_shard[d] += per
        else:
            self.ledger.escrow_fill(o.victim, k, requester=o.requester,
                                    dev=shard)
            o.filled_by_shard[shard] += k
        o.filled += k
        delta_coherent = o.coherent_filled - old_coherent
        g.pending -= k
        g.available += delta_coherent
        g.incoherent += k - delta_coherent
        self.steal_log.append(StealRecord(
            requester=o.requester, victim=o.victim, units=k,
            wall_seconds=wall,
            reclaimed_bytes=ev.reclaimed_bytes if ev is not None else 0,
            migrated_bytes=ev.migrated_bytes if ev is not None else 0,
            mode=self._mode.get(o.victim), natural=natural))
        if not o.open:
            self._close_order(o)

    def cancel_order(self, order_id: int, units: Optional[int] = None,
                     shard: Optional[int] = None) -> int:
        """Victim abandons (part of) an order it cannot fulfill — e.g. its
        arena is fully drained, or it finished naturally and released its
        memory before the order could be serviced.  The requester's pending
        shrinks; it may re-request later.  Returns units canceled.

        ``shard=d`` cancels one device shard's remainder (its siblings
        stay ordered); ``shard=None`` cancels across every shard.  A
        cancel can strand already-drained sibling fills incoherent —
        when the order closes, that stranded escrow is unwound back to
        the free pool (``_close_order``), never silently leaked."""
        o = self.orders[order_id]
        g = self._order_grant[o.order_id]
        n = 0
        if shard is not None:
            assert 0 <= shard < o.shards, (shard, o.shards)
            n = o.shard_remaining(shard) if units is None \
                else min(units, o.shard_remaining(shard))
            o.canceled_by_shard[shard] += n
        else:
            want = o.remaining if units is None else min(units, o.remaining)
            left = want
            for d in range(o.shards):       # drain shard remainders in order
                k = min(left, o.shard_remaining(d))
                o.canceled_by_shard[d] += k
                left -= k
            n = want - left
        if n <= 0:
            return 0
        o.canceled += n
        g.pending -= n
        self.denied_units += n
        if not o.open:
            self._close_order(o)
        self._prune_grant(g)
        return n

    def _close_order(self, o: ReclaimOrder) -> None:
        o.closed_at = self._clock()
        vlist = self._victim_orders.get(o.victim)
        if vlist and o.order_id in vlist:
            vlist.remove(o.order_id)
        # shard-coherence settlement: fills that never got their sibling
        # shards (the victim canceled those) are stranded — they can
        # never become claimable, so unwind them escrow -> free on their
        # exact devices and count them denied.  1-shard orders close with
        # min == filled, so nothing is ever stranded on the legacy path.
        if o.shards > 1:
            g = self._order_grant[o.order_id]
            floor = min(o.filled_by_shard)
            for d in range(o.shards):
                stranded = o.filled_by_shard[d] - floor
                if stranded > 0:
                    self.ledger.escrow_release(stranded,
                                               requester=o.requester,
                                               dev=d)
                    g.incoherent -= stranded
                    self.denied_units += stranded
            self._prune_grant(g)

    def _prune_grant(self, g: Grant) -> None:
        if g.done and g.available == 0 and g.incoherent == 0 \
                and g in self.grants:
            self.grants.remove(g)

    def abandon_grant(self, grant: Grant) -> int:
        """Requester gives up on a pending grant (its demand vanished, or
        it is shutting down): cancel the unfilled remainder of the backing
        orders.  Already-escrowed units stay claimable.  Returns units
        canceled."""
        n = 0
        for oid in list(grant.order_ids):
            if self.orders[oid].open:
                n += self.cancel_order(oid)
        return n

    def claim_grant(self, grant: Grant) -> int:
        """Requester-side grant completion: absorb escrowed units (the
        engine then grows its rows at its own tick boundary)."""
        k = grant.available
        if k <= 0:
            self._prune_grant(grant)
            return 0
        grant.available = 0
        grant.claimed += k
        self.ledger.escrow_claim(grant.replica_id, k)
        self._prune_grant(grant)
        return k

    # ----------------------------------------------------- pressure signal
    def open_order_units(self, replica_id: str) -> int:
        """Blocks this replica still owes to open reclaim orders — the
        router's drain-awareness signal."""
        return sum(self.orders[oid].remaining
                   for oid in self._victim_orders.get(replica_id, ()))

    def pending_units(self) -> int:
        return sum(o.remaining for o in self.orders.values())

    def escrow_units(self) -> int:
        return self.ledger.escrow_units

    def pressure(self) -> float:
        """Outstanding pending units / budget: how far the host is from
        satisfying every open plug request."""
        return self.pending_units() / self.budget_units

    # ------------------------------------------------- sync reclaim (legacy)
    def _reclaim_from_idlest(self, requester: str, deficit: int) -> float:
        """Host pressure, synchronous: shrink other replicas inline, idlest
        first (fewest in-flight invocations — the VM whose reclaim disturbs
        least).  Returns the victim-side wall the requester waited for.

        ``_inline_reclaim`` fences the snapshot pool for the duration:
        every unit a victim surrenders here already belongs to the open
        grant, so a victim's eviction path must not be able to divert
        free units into a snapshot capture mid-steal (``snapshot_room``
        declines, so the victim skips the readout entirely — and the
        capture would be immediate squeeze-bait anyway)."""
        victims = sorted(
            (r for r in self.granted
             if r != requester and r in self._reclaim),
            key=lambda r: (self._load[r]() if r in self._load else 0, r))
        stall = 0.0
        self._inline_reclaim = True
        try:
            for v in victims:
                if deficit <= 0:
                    break
                t0 = self._clock()
                got, ev = self._reclaim[v](deficit)
                wall = ev.wall_seconds if ev is not None \
                    else self._clock() - t0
                if got <= 0:
                    continue
                assert got <= self.granted[v]
                self.ledger.release(v, got)
                deficit -= got
                stall += wall
                self.steal_log.append(StealRecord(
                    requester=requester, victim=v, units=got,
                    wall_seconds=wall,
                    reclaimed_bytes=(ev.reclaimed_bytes
                                     if ev is not None else 0),
                    migrated_bytes=(ev.migrated_bytes
                                    if ev is not None else 0),
                    mode=self._mode.get(v)))
        finally:
            self._inline_reclaim = False
        return stall

    # -------------------------------------------------------------- report
    def report(self) -> dict[str, Any]:
        """Host-level reclaim telemetry (per-mode steal latency — the
        cluster analogue of the paper's Fig. 5)."""
        by_mode: dict[str, dict[str, float]] = {}
        for rec in self.steal_log:
            d = by_mode.setdefault(rec.mode or "?", {
                "steals": 0, "units": 0, "wall_seconds": 0.0,
                "reclaimed_bytes": 0, "migrated_bytes": 0})
            d["steals"] += 1
            d["units"] += rec.units
            d["wall_seconds"] += rec.wall_seconds
            d["reclaimed_bytes"] += rec.reclaimed_bytes
            d["migrated_bytes"] += rec.migrated_bytes
        return {
            "budget_units": self.budget_units,
            "free_units": self.free_units,
            "granted": dict(self.granted),
            "steals": len(self.steal_log),
            "stolen_units": sum(r.units for r in self.steal_log),
            "grant_calls": self.grant_calls,
            "denied_units": self.denied_units,
            "async": self.async_reclaim,
            "orders": len(self.orders),
            "pending_units": self.pending_units(),
            "escrow_units": self.escrow_units(),
            "pressure": self.pressure(),
            "by_mode": by_mode,
            "devices": self.ledger.device_report(),
            "snapshot_units": self.snapshot_units(),
            "referenced_snapshot_units": (
                self.snapshots.referenced_units
                if self.snapshots is not None else 0),
            "snapshot_squeezes": len(self.squeeze_log),
            "squeezed_units": sum(r.units for r in self.squeeze_log),
            "snapshots": (self.snapshots.report()
                          if self.snapshots is not None else None),
            "tenants": self.ledger.tenant_report(),
        }

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        # conservation (free + granted + escrow + snapshot == budget) is
        # the ledger's single check; the broker asserts its order/grant/
        # pool structures agree with the ledger's accounts
        self.ledger.check()
        assert self.ledger.escrow_units \
            == sum(g.available + g.incoherent for g in self.grants), \
            "escrow not backed by open grants"
        assert self.ledger.snapshot_units == self.snapshot_units(), \
            "pool charge diverged from the ledger"
        if self.snapshots is not None:
            self.snapshots.check_invariants()
            # per-tenant cross-check: legacy entries grouped by owner plus
            # unique pages grouped by their CHARGED owner must sum to the
            # ledger's tenant snapshot accounts — so an evicted shared
            # page can never strand charge on a departed tenant
            by_tenant: dict[str, int] = {}
            for k in self.snapshots.keys():
                s = self.snapshots.peek(k)
                if s.pages is not None:
                    continue                # charged via the page store
                t = s.tenant or self.ledger.resolve_tenant(None)
                by_tenant[t] = by_tenant.get(t, 0) + s.units
            for t, u in self.snapshots.pages.owner_units().items():
                t = t or self.ledger.resolve_tenant(None)
                by_tenant[t] = by_tenant.get(t, 0) + u
            for t in self.ledger.sub_budgets:
                assert by_tenant.get(t, 0) == self.ledger.tenant_snapshot(t), \
                    f"tenant {t} pool entries diverged from ledger account"
        for o in self.orders.values():
            assert 0 <= o.filled + o.canceled <= o.units, o
            # the shard vectors ARE the order's state: their sums must
            # match the scalar totals and no shard may exceed its slice
            assert sum(o.filled_by_shard) == o.filled, o
            assert sum(o.canceled_by_shard) == o.canceled, o
            for d in range(o.shards):
                assert 0 <= o.filled_by_shard[d] + o.canceled_by_shard[d] \
                    <= o.per_shard, o
            if o.open:
                assert o.order_id in self._victim_orders.get(o.victim, ()), o
        for g in self.grants:
            assert g.pending >= 0 and g.available >= 0, g
            assert g.incoherent >= 0, g
            assert g.fulfilled <= g.requested, g
            # LOUD shard-coherence law: once every backing order has
            # closed, no incoherent escrow may remain — a fill that
            # reached only some shards of a victim must have been either
            # completed by its siblings or unwound at order close.  A
            # grant stuck incoherent here means a drain path skewed
            # shards silently (the sharded analogue of a row-skew bug).
            if all(not self.orders[oid].open for oid in g.order_ids):
                assert g.incoherent == 0, \
                    f"shard-incoherent drain: grant for {g.replica_id} " \
                    f"holds {g.incoherent} escrowed units that can never " \
                    f"become claimable (orders all closed)"
        # every pending unit is backed by exactly one open order
        assert sum(g.pending for g in self.grants) \
            == sum(o.remaining for o in self.orders.values()), \
            "pending units not backed by open orders"
        # incoherent escrow is exactly the open orders' uncovered stripes
        assert sum(g.incoherent for g in self.grants) \
            == sum(o.filled - o.coherent_filled
                   for o in self.orders.values() if o.open), \
            "incoherent escrow diverged from open orders' shard skew"
