"""Host memory broker: the hypervisor/virtio-mem role of the paper, §2+§4.

One physical host runs N VM-sandboxed replicas (each a ``ServeEngine``) and
owns a fixed budget of memory units.  The broker is the host-side control
plane that arbitrates that budget; its verbs map onto the paper's terms:

  broker verb               paper mechanism
  -----------------------   --------------------------------------------
  ``register``              VM boot: the guest's initial memory plug
  ``request_units``         virtio-mem **plug** request (guest asks the
                            hypervisor for more memory blocks)
  ``release_units``         virtio-mem **unplug** completion (guest hands
                            reclaimed blocks back to the host)
  ``_reclaim_from_idlest``  host memory pressure: the hypervisor shrinks
                            the idlest VM (Squeezy's sub-second reclaim is
                            what makes this cheap enough to do online)
  unit (= one block)        a Linux 128 MiB memory block — here one
                            ``block_tokens`` slab of arena state

A unit is a *block* (``ArenaSpec.block_tokens`` worth of state), the finest
granularity both managers share; HotMem replicas convert partitions to
blocks at the boundary (1 partition = ``blocks_per_partition`` units).

Conservation invariant (the test suite's anchor): at all times
``free_units + sum(granted.values()) == budget_units`` — the host never
double-grants a unit and never leaks one.

``AlwaysGrantBroker`` is the single-replica degenerate case: an unmetered
host that grants every request, so a lone ``ServeEngine`` behaves exactly
as it did before the broker existed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

from repro.core.arena import ReclaimEvent

# victim-side reclaim callback: (k_units) -> (units_reclaimed, event|None)
ReclaimFn = Callable[[int], tuple[int, Optional[ReclaimEvent]]]


@dataclasses.dataclass
class StealRecord:
    """One host-pressure reclaim: the broker shrank ``victim`` to feed
    ``requester`` (the paper's headline metric is how fast this is)."""
    requester: str
    victim: str
    units: int                   # blocks moved from victim to the free pool
    wall_seconds: float          # victim-side reclaim latency
    reclaimed_bytes: int
    migrated_bytes: int          # 0 for hotmem victims by construction
    mode: Optional[str] = None   # victim's manager mode


class MemoryBroker:
    """Interface: what a replica needs from its host."""

    def register(self, replica_id: str, initial_units: int, *,
                 reclaim: Optional[ReclaimFn] = None,
                 load: Optional[Callable[[], int]] = None,
                 mode: Optional[str] = None) -> None:
        raise NotImplementedError

    def request_units(self, replica_id: str, want: int) -> int:
        raise NotImplementedError

    def release_units(self, replica_id: str, units: int) -> None:
        raise NotImplementedError


class AlwaysGrantBroker(MemoryBroker):
    """Unmetered host: every plug request is granted in full.  Used by a
    standalone ``ServeEngine`` so single-replica behavior is unchanged."""

    def register(self, replica_id: str, initial_units: int, **_: Any) -> None:
        pass

    def request_units(self, replica_id: str, want: int) -> int:
        return max(want, 0)

    def release_units(self, replica_id: str, units: int) -> None:
        pass


class HostMemoryBroker(MemoryBroker):
    """Fixed-budget host arbiter: grant on demand, reclaim-from-idlest
    under pressure."""

    def __init__(self, budget_units: int):
        assert budget_units > 0
        self.budget_units = budget_units
        self.free_units = budget_units
        self.granted: dict[str, int] = {}
        self._reclaim: dict[str, ReclaimFn] = {}
        self._load: dict[str, Callable[[], int]] = {}
        self._mode: dict[str, Optional[str]] = {}
        self.steal_log: list[StealRecord] = []
        self.grant_calls = 0
        self.denied_units = 0        # requested-but-ungranted (pressure)

    # ----------------------------------------------------------- lifecycle
    def register(self, replica_id: str, initial_units: int, *,
                 reclaim: Optional[ReclaimFn] = None,
                 load: Optional[Callable[[], int]] = None,
                 mode: Optional[str] = None) -> None:
        """VM boot: carve the replica's initial plug out of the free pool."""
        assert replica_id not in self.granted, replica_id
        assert initial_units <= self.free_units, \
            f"host budget exhausted registering {replica_id}: " \
            f"need {initial_units}, free {self.free_units}"
        self.free_units -= initial_units
        self.granted[replica_id] = initial_units
        if reclaim is not None:
            self._reclaim[replica_id] = reclaim
        if load is not None:
            self._load[replica_id] = load
        self._mode[replica_id] = mode

    # --------------------------------------------------------- plug/unplug
    def request_units(self, replica_id: str, want: int) -> int:
        """virtio-mem plug: grant up to ``want`` units, stealing from the
        idlest other replicas if the free pool can't cover it."""
        assert replica_id in self.granted, replica_id
        if want <= 0:
            return 0
        self.grant_calls += 1
        if self.free_units < want:
            self._reclaim_from_idlest(replica_id, want - self.free_units)
        g = min(want, self.free_units)
        self.free_units -= g
        self.granted[replica_id] += g
        self.denied_units += want - g
        return g

    def release_units(self, replica_id: str, units: int) -> None:
        """virtio-mem unplug completion: units return to the host pool."""
        if units <= 0:
            return
        assert self.granted.get(replica_id, 0) >= units, \
            f"{replica_id} returning {units} units it was never granted"
        self.granted[replica_id] -= units
        self.free_units += units

    def _reclaim_from_idlest(self, requester: str, deficit: int) -> None:
        """Host pressure: shrink other replicas, idlest first (fewest
        in-flight invocations — the VM whose reclaim disturbs least)."""
        victims = sorted(
            (r for r in self.granted
             if r != requester and r in self._reclaim),
            key=lambda r: (self._load[r]() if r in self._load else 0, r))
        for v in victims:
            if deficit <= 0:
                break
            t0 = time.perf_counter()
            got, ev = self._reclaim[v](deficit)
            wall = time.perf_counter() - t0
            if got <= 0:
                continue
            assert got <= self.granted[v]
            self.granted[v] -= got
            self.free_units += got
            deficit -= got
            self.steal_log.append(StealRecord(
                requester=requester, victim=v, units=got,
                wall_seconds=ev.wall_seconds if ev is not None else wall,
                reclaimed_bytes=ev.reclaimed_bytes if ev is not None else 0,
                migrated_bytes=ev.migrated_bytes if ev is not None else 0,
                mode=self._mode.get(v)))

    # -------------------------------------------------------------- report
    def report(self) -> dict[str, Any]:
        """Host-level reclaim telemetry (per-mode steal latency — the
        cluster analogue of the paper's Fig. 5)."""
        by_mode: dict[str, dict[str, float]] = {}
        for rec in self.steal_log:
            d = by_mode.setdefault(rec.mode or "?", {
                "steals": 0, "units": 0, "wall_seconds": 0.0,
                "reclaimed_bytes": 0, "migrated_bytes": 0})
            d["steals"] += 1
            d["units"] += rec.units
            d["wall_seconds"] += rec.wall_seconds
            d["reclaimed_bytes"] += rec.reclaimed_bytes
            d["migrated_bytes"] += rec.migrated_bytes
        return {
            "budget_units": self.budget_units,
            "free_units": self.free_units,
            "granted": dict(self.granted),
            "steals": len(self.steal_log),
            "grant_calls": self.grant_calls,
            "denied_units": self.denied_units,
            "by_mode": by_mode,
        }

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        assert self.free_units >= 0
        assert all(g >= 0 for g in self.granted.values())
        assert self.free_units + sum(self.granted.values()) \
            == self.budget_units, "host units leaked or double-granted"
