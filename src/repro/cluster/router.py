"""Invocation router: spreads a shared trace across replicas.

The FaaS front-end analogue: a host (or fleet of hosts) runs N replicas
and every incoming invocation must be assigned to one.  Policies:

  * ``least_loaded``  — send to the replica with the fewest in-flight +
                        queued invocations (classic load spreading).
  * ``warm_affinity`` — prefer a replica holding a warm (kept-alive)
                        container for the same function profile, so the
                        invocation skips prefill (the paper's warm-start
                        fast path); falls back to least-loaded.
  * ``power_of_two``  — power-of-two-choices: sample two replicas (seeded
                        rng, deterministic for a fixed trace) and take the
                        less loaded — but first avoid the one that is
                        mid-reclaim (open ``ReclaimOrder``s reported by
                        the broker's pressure signal): routing onto a
                        draining victim both slows its drain and lands
                        the invocation on a shrinking arena.
  * ``snapshot_affinity`` — warm row > host snapshot > any replica: a
                        warm container is still the fastest start, but
                        when none exists and the host snapshot pool holds
                        the function's prefix KV (see
                        ``repro.cluster.snapshots``), ANY replica can
                        restore it — the pool is host-wide, à la
                        TrEnv-X's remote snapshot pools — so the pick
                        degrades to least-loaded among non-draining
                        replicas (a restore adds memory demand, which a
                        mid-reclaim victim should not absorb).
  * ``drain_weighted`` — the fleet-aware policy: replicas are ranked by
                        start-path tier, then by a WEIGHTED drain score.
                        Tiers, fastest start first:

                          0. local warm row (adopt, zero copy);
                          1. replica whose own host's pool holds a
                             restorable snapshot (local restore);
                          2. some OTHER host holds it (remote snapshot:
                             the fleet migrates it to the chosen host,
                             paying the modeled inter-host copy — see
                             ``repro.cluster.fleet``);
                          3. nothing cached anywhere (cold prefill).

                        Within a tier the key is ``(open_order_units,
                        load, id)`` — unlike the binary dodge above, a
                        replica owing 1 block outranks one owing 20, so
                        pressure spreads by *magnitude*, not presence.
  * ``slo_tiered``    — latency-tiered spending of cached warm state:
                        a "tight"/"standard" invocation routes exactly
                        like ``drain_weighted`` (warm > local snapshot >
                        remote snapshot > cold), but a "batch" invocation
                        (``slo_tier_of(req) == "batch"``) deliberately
                        AVOIDS replicas holding a warm row for its
                        profile — batch traffic must not consume (or
                        refresh) the warm/snapshot capacity the tight
                        tier's tail depends on — and spreads cold by the
                        weighted drain key.  ``tight_routes`` /
                        ``batch_routes`` count the per-tier assignments.

Ties break on replica id, so routing is deterministic for a fixed trace.
A custom ``route_fn(req, engines) -> replica_id`` overrides the policy
(benchmarks use this to pin tenants to replicas).

``broker`` (optional) supplies the drain-awareness signal
(``open_order_units``) and the restore-feasibility probe
(``snapshot_restorable`` — entry present AND payload to copy back, so
the router never predicts a restore that cannot happen).  ``fleet``
(optional, a ``repro.cluster.fleet.FleetScheduler``) supplies the same
signals fleet-wide — per-replica host brokers via ``broker_of`` and the
cross-host snapshot view via ``snapshot_host``.  ``ClusterSim`` wires
its broker in automatically; ``FleetSim`` wires the scheduler.

Accounting: ``warm_routes`` / ``snapshot_routes`` / ``remote_routes``
count ROUTE-TIME picks — the replica looked warm (resp. a local / remote
pool held a snapshot) when the arrival was assigned.  They are
predictions, not outcomes: keep-alive expiry can recycle the warm
container (or pressure can squeeze the snapshot) before the invocation's
``submit_s`` arrives, in which case the engine silently cold-starts.
The authoritative hit counters live engine-side (``ServeEngine``'s
``warm_starts`` / ``restore_starts`` / ``remote_restore_starts``,
surfaced as ``warm_hits`` etc. in the sim metrics): they count the start
path that actually ran.  ``drain_avoided`` counts picks the drain term
changed (vs. pure load order) under ANY drain-aware policy.
"""
from __future__ import annotations

import random
from typing import Callable, Optional

from repro.serving.request import slo_tier_of

POLICIES = ("least_loaded", "warm_affinity", "power_of_two",
            "snapshot_affinity", "drain_weighted", "slo_tiered")


class Router:
    def __init__(self, policy: str = "least_loaded",
                 route_fn: Optional[Callable] = None,
                 broker=None, fleet=None, seed: int = 0):
        assert route_fn is not None or policy in POLICIES, policy
        self.policy = policy
        self.route_fn = route_fn
        self.broker = broker
        self.fleet = fleet
        self._rng = random.Random(seed)
        self.routed: dict[str, int] = {}      # replica -> #assigned
        self.warm_routes = 0                  # route-time warm picks
        self.snapshot_routes = 0              # route-time local-pool picks
        self.remote_routes = 0                # route-time remote-pool picks
        self.drain_avoided = 0                # picks the drain term changed
        self.tight_routes = 0                 # slo_tiered: non-batch picks
        self.batch_routes = 0                 # slo_tiered: batch picks

    def _score(self, rid: str, engines, backlog) -> tuple[int, str]:
        load = engines[rid].load() + (backlog or {}).get(rid, 0)
        return (load, rid)

    def _draining(self, rid: str) -> int:
        """Blocks ``rid`` still owes to open reclaim orders (0 without a
        broker/fleet or for brokers without the async order plane)."""
        if self.broker is not None:
            fn = getattr(self.broker, "open_order_units", None)
            return fn(rid) if fn is not None else 0
        if self.fleet is not None:
            return self.fleet.open_order_units(rid)
        return 0

    def _key(self, rid: str, engines, backlog, *, weighted: bool
             ) -> tuple[int, tuple[int, str]]:
        """THE drain-aware routing key, shared by every policy that
        dodges mid-reclaim victims: (drain penalty, load, id).  The
        legacy policies use a binary penalty (any open order at all);
        ``drain_weighted`` ranks by how MANY blocks the replica owes."""
        owed = self._draining(rid)
        return (owed if weighted else int(owed > 0),
                self._score(rid, engines, backlog))

    def _pick(self, cands, engines, backlog, *, weighted: bool = False
              ) -> str:
        """Min over the shared key; counts ``drain_avoided`` whenever the
        drain term changed the pick vs. pure load order."""
        rid = min(cands, key=lambda r: self._key(r, engines, backlog,
                                                 weighted=weighted))
        by_load = min(cands, key=lambda r: self._score(r, engines, backlog))
        if rid != by_load:
            self.drain_avoided += 1
        return rid

    # ------------------------------------------------- snapshot visibility
    def _host_broker(self, rid: str):
        """The broker arbitrating ``rid``'s host (single-host: the wired
        broker; fleet: that replica's placement)."""
        if self.broker is not None:
            return self.broker
        if self.fleet is not None:
            return self.fleet.broker_of(rid)
        return None

    def device_headroom(self, rid: str) -> Optional[int]:
        """Observability probe: the BALANCED free headroom of ``rid``'s
        host (scarcest device × device count — what a sharded plug could
        actually take).  Surfaced for reports and demos only; it is
        deliberately NOT part of any routing key, so a ``devices=1``
        topology replays every routing trace bit-identically."""
        b = self._host_broker(rid)
        led = getattr(b, "ledger", None) if b is not None else None
        return led.balanced_free() if led is not None else None

    def _snapshot_restorable(self, profile_name: str) -> bool:
        """Host-wide probe (snapshot_affinity): does THE host's pool —
        or, fleet-wired, any host's — hold a restorable copy?"""
        if self.broker is not None:
            fn = getattr(self.broker, "snapshot_restorable", None)
            return bool(fn(profile_name)) if fn is not None else False
        if self.fleet is not None:
            return self.fleet.snapshot_host(profile_name) is not None
        return False

    def _restorable_on(self, rid: str, profile_name: str) -> bool:
        """Per-replica probe (drain_weighted tier 1): restorable from the
        pool of ``rid``'s OWN host, i.e. without a cross-host copy."""
        b = self._host_broker(rid)
        fn = getattr(b, "snapshot_restorable", None) if b is not None \
            else None
        return bool(fn(profile_name)) if fn is not None else False

    def _tier(self, rid: str, req, engines, remote_exists: bool) -> int:
        """``drain_weighted``'s start-path tier for ``rid`` (see module
        docstring): 0 warm, 1 local snapshot, 2 remote snapshot, 3 cold.
        ``remote_exists`` (does ANY host's pool hold the key?) is replica-
        independent, so the caller probes it once per arrival."""
        key = req.profile.name
        if engines[rid].warm.get(key):
            return 0
        if self._restorable_on(rid, key):
            return 1
        return 2 if remote_exists else 3

    def _route_tiered(self, req, engines: dict, backlog) -> str:
        """The start-path-tiered pick (``drain_weighted``'s core, shared
        with ``slo_tiered``'s non-batch traffic): best tier wins, weighted
        drain key within the tier, per-tier route counters."""
        remote = self.fleet is not None and \
            self.fleet.snapshot_host(req.profile.name) is not None
        tiers = {r: self._tier(r, req, engines, remote)
                 for r in engines}
        best = min(tiers.values())
        rid = self._pick([r for r in engines if tiers[r] == best],
                         engines, backlog, weighted=True)
        if best == 0:
            self.warm_routes += 1
        elif best == 1:
            self.snapshot_routes += 1
        elif best == 2:
            self.remote_routes += 1
        return rid

    def _mask_lifecycle(self, engines: dict) -> dict:
        """Drop replicas on retiring (or already-retired) hosts — and on
        hosts still PROVISIONING (booted but not yet ready) — from the
        candidate set: EVERY tier of every policy skips them, since a
        retiring host accepts no new work and a booting one cannot serve
        it yet.  Falls back to the full set if nothing survives the mask
        (an arrival must route somewhere)."""
        f = self.fleet
        if f is None:
            return engines
        ready = getattr(f, "host_ready", lambda h: True)
        if not (getattr(f, "retiring", None) or getattr(f, "retired", None)
                or getattr(f, "_ready_at", None)):
            return engines
        live = {r: e for r, e in engines.items()
                if (h := f.host_of(r)) is None
                or (h in f.brokers and h not in f.retiring and ready(h))}
        return live or engines

    def route(self, req, engines: dict, backlog: Optional[dict] = None
              ) -> str:
        """Pick the replica for ``req``.  ``backlog`` counts routed-but-
        not-yet-submitted invocations per replica (the router's own queue
        view, so bursts don't all land on one replica)."""
        if self.route_fn is not None:
            rid = self.route_fn(req, engines)
        else:
            engines = self._mask_lifecycle(engines)
            rid = None
            if self.policy in ("warm_affinity", "snapshot_affinity"):
                warm = [r for r, e in engines.items()
                        if e.warm.get(req.profile.name)]
                if warm:
                    rid = min(warm,
                              key=lambda r: self._score(r, engines, backlog))
                    self.warm_routes += 1
            if rid is None and self.policy == "snapshot_affinity" \
                    and self._snapshot_restorable(req.profile.name):
                # the pool is host-wide: any replica restores equally well,
                # so spread by load but dodge mid-reclaim victims
                rid = self._pick(list(engines), engines, backlog)
                self.snapshot_routes += 1
            elif rid is None and self.policy == "drain_weighted":
                rid = self._route_tiered(req, engines, backlog)
            elif rid is None and self.policy == "slo_tiered":
                if slo_tier_of(req) == "batch":
                    # batch must not consume warm capacity: avoid replicas
                    # holding a warm row for this profile (unless every
                    # replica does), spread by the weighted drain key
                    key = req.profile.name
                    cold = [r for r, e in engines.items()
                            if not e.warm.get(key)]
                    rid = self._pick(cold or list(engines), engines,
                                     backlog, weighted=True)
                    self.batch_routes += 1
                else:
                    rid = self._route_tiered(req, engines, backlog)
                    self.tight_routes += 1
            elif rid is None and self.policy == "power_of_two":
                ids = sorted(engines)
                pair = ids if len(ids) <= 2 else self._rng.sample(ids, 2)
                rid = self._pick(pair, engines, backlog)
            if rid is None and self.policy == "snapshot_affinity":
                # the cold fallback must honor the docstring's promise —
                # "least-loaded among NON-DRAINING replicas": pure load
                # order here used to land invocations on mid-reclaim
                # victims exactly when nothing was cached
                rid = self._pick(list(engines), engines, backlog)
            if rid is None:
                rid = min(engines,
                          key=lambda r: self._score(r, engines, backlog))
        self.routed[rid] = self.routed.get(rid, 0) + 1
        return rid
