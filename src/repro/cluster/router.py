"""Invocation router: spreads a shared trace across replicas.

The FaaS front-end analogue: a host runs N replicas and every incoming
invocation must be assigned to one.  Policies:

  * ``least_loaded``  — send to the replica with the fewest in-flight +
                        queued invocations (classic load spreading).
  * ``warm_affinity`` — prefer a replica holding a warm (kept-alive)
                        container for the same function profile, so the
                        invocation skips prefill (the paper's warm-start
                        fast path); falls back to least-loaded.
  * ``power_of_two``  — power-of-two-choices: sample two replicas (seeded
                        rng, deterministic for a fixed trace) and take the
                        less loaded — but first avoid the one that is
                        mid-reclaim (open ``ReclaimOrder``s reported by
                        the broker's pressure signal): routing onto a
                        draining victim both slows its drain and lands
                        the invocation on a shrinking arena.
  * ``snapshot_affinity`` — warm row > host snapshot > any replica: a
                        warm container is still the fastest start, but
                        when none exists and the host snapshot pool holds
                        the function's prefix KV (see
                        ``repro.cluster.snapshots``), ANY replica can
                        restore it — the pool is host-wide, à la
                        TrEnv-X's remote snapshot pools — so the pick
                        degrades to least-loaded among non-draining
                        replicas (a restore adds memory demand, which a
                        mid-reclaim victim should not absorb).

Ties break on replica id, so routing is deterministic for a fixed trace.
A custom ``route_fn(req, engines) -> replica_id`` overrides the policy
(benchmarks use this to pin tenants to replicas).

``broker`` (optional) supplies the drain-awareness signal
(``open_order_units``) and the restore-feasibility probe
(``snapshot_restorable`` — entry present AND payload to copy back, so
the router never predicts a restore that cannot happen); ``ClusterSim``
wires its broker in automatically when the router was constructed
without one.

Accounting: ``warm_routes`` / ``snapshot_routes`` count ROUTE-TIME picks —
the replica looked warm (resp. the pool held a snapshot) when the arrival
was assigned.  They are predictions, not outcomes: keep-alive expiry can
recycle the warm container (or pressure can squeeze the snapshot) before
the invocation's ``submit_s`` arrives, in which case the engine silently
cold-starts.  The authoritative hit counters live engine-side
(``ServeEngine.warm_starts`` / ``restore_starts``, surfaced as
``warm_hits`` / ``restore_starts`` in ``ClusterSim.metrics``): they count
``_start_warm`` / ``_start_restore`` actually running.
"""
from __future__ import annotations

import random
from typing import Callable, Optional

POLICIES = ("least_loaded", "warm_affinity", "power_of_two",
            "snapshot_affinity")


class Router:
    def __init__(self, policy: str = "least_loaded",
                 route_fn: Optional[Callable] = None,
                 broker=None, seed: int = 0):
        assert route_fn is not None or policy in POLICIES, policy
        self.policy = policy
        self.route_fn = route_fn
        self.broker = broker
        self._rng = random.Random(seed)
        self.routed: dict[str, int] = {}      # replica -> #assigned
        self.warm_routes = 0                  # route-time warm picks
        self.snapshot_routes = 0              # route-time snapshot picks
        self.drain_avoided = 0                # times p2c dodged a victim

    def _score(self, rid: str, engines, backlog) -> tuple[int, str]:
        load = engines[rid].load() + (backlog or {}).get(rid, 0)
        return (load, rid)

    def _draining(self, rid: str) -> int:
        """Blocks ``rid`` still owes to open reclaim orders (0 without a
        broker or for brokers without the async order plane)."""
        if self.broker is None:
            return 0
        fn = getattr(self.broker, "open_order_units", None)
        return fn(rid) if fn is not None else 0

    def _snapshot_restorable(self, profile_name: str) -> bool:
        if self.broker is None:
            return False
        fn = getattr(self.broker, "snapshot_restorable", None)
        return bool(fn(profile_name)) if fn is not None else False

    def route(self, req, engines: dict, backlog: Optional[dict] = None
              ) -> str:
        """Pick the replica for ``req``.  ``backlog`` counts routed-but-
        not-yet-submitted invocations per replica (the router's own queue
        view, so bursts don't all land on one replica)."""
        if self.route_fn is not None:
            rid = self.route_fn(req, engines)
        else:
            rid = None
            if self.policy in ("warm_affinity", "snapshot_affinity"):
                warm = [r for r, e in engines.items()
                        if e.warm.get(req.profile.name)]
                if warm:
                    rid = min(warm,
                              key=lambda r: self._score(r, engines, backlog))
                    self.warm_routes += 1
            if rid is None and self.policy == "snapshot_affinity" \
                    and self._snapshot_restorable(req.profile.name):
                # the pool is host-wide: any replica restores equally well,
                # so spread by load but dodge mid-reclaim victims
                rid = min(engines, key=lambda r: (
                    1 if self._draining(r) else 0,
                    self._score(r, engines, backlog)))
                self.snapshot_routes += 1
            elif rid is None and self.policy == "power_of_two":
                ids = sorted(engines)
                pair = ids if len(ids) <= 2 else self._rng.sample(ids, 2)
                rid = min(pair, key=lambda r: (
                    1 if self._draining(r) else 0,
                    self._score(r, engines, backlog)))
                by_load = min(pair,
                              key=lambda r: self._score(r, engines, backlog))
                if rid != by_load:       # the drain tiebreak changed the pick
                    self.drain_avoided += 1
            if rid is None:
                rid = min(engines,
                          key=lambda r: self._score(r, engines, backlog))
        self.routed[rid] = self.routed.get(rid, 0) + 1
        return rid
