"""Invocation router: spreads a shared trace across replicas.

The FaaS front-end analogue: a host runs N replicas and every incoming
invocation must be assigned to one.  Policies:

  * ``least_loaded``  — send to the replica with the fewest in-flight +
                        queued invocations (classic load spreading).
  * ``warm_affinity`` — prefer a replica holding a warm (kept-alive)
                        container for the same function profile, so the
                        invocation skips prefill (the paper's warm-start
                        fast path); falls back to least-loaded.

Ties break on replica id, so routing is deterministic for a fixed trace.
A custom ``route_fn(req, engines) -> replica_id`` overrides the policy
(benchmarks use this to pin tenants to replicas).
"""
from __future__ import annotations

from typing import Callable, Optional

POLICIES = ("least_loaded", "warm_affinity")


class Router:
    def __init__(self, policy: str = "least_loaded",
                 route_fn: Optional[Callable] = None):
        assert route_fn is not None or policy in POLICIES, policy
        self.policy = policy
        self.route_fn = route_fn
        self.routed: dict[str, int] = {}      # replica -> #assigned
        self.warm_hits = 0

    def _score(self, rid: str, engines, backlog) -> tuple[int, str]:
        load = engines[rid].load() + (backlog or {}).get(rid, 0)
        return (load, rid)

    def route(self, req, engines: dict, backlog: Optional[dict] = None
              ) -> str:
        """Pick the replica for ``req``.  ``backlog`` counts routed-but-
        not-yet-submitted invocations per replica (the router's own queue
        view, so bursts don't all land on one replica)."""
        if self.route_fn is not None:
            rid = self.route_fn(req, engines)
        else:
            rid = None
            if self.policy == "warm_affinity":
                warm = [r for r, e in engines.items()
                        if e.warm.get(req.profile.name)]
                if warm:
                    rid = min(warm,
                              key=lambda r: self._score(r, engines, backlog))
                    self.warm_hits += 1
            if rid is None:
                rid = min(engines,
                          key=lambda r: self._score(r, engines, backlog))
        self.routed[rid] = self.routed.get(rid, 0) + 1
        return rid
