"""Invocation router: spreads a shared trace across replicas.

The FaaS front-end analogue: a host runs N replicas and every incoming
invocation must be assigned to one.  Policies:

  * ``least_loaded``  — send to the replica with the fewest in-flight +
                        queued invocations (classic load spreading).
  * ``warm_affinity`` — prefer a replica holding a warm (kept-alive)
                        container for the same function profile, so the
                        invocation skips prefill (the paper's warm-start
                        fast path); falls back to least-loaded.
  * ``power_of_two``  — power-of-two-choices: sample two replicas (seeded
                        rng, deterministic for a fixed trace) and take the
                        less loaded — but first avoid the one that is
                        mid-reclaim (open ``ReclaimOrder``s reported by
                        the broker's pressure signal): routing onto a
                        draining victim both slows its drain and lands
                        the invocation on a shrinking arena.

Ties break on replica id, so routing is deterministic for a fixed trace.
A custom ``route_fn(req, engines) -> replica_id`` overrides the policy
(benchmarks use this to pin tenants to replicas).

``broker`` (optional) supplies the drain-awareness signal
(``open_order_units``); ``ClusterSim`` wires its broker in automatically
when the router was constructed without one.
"""
from __future__ import annotations

import random
from typing import Callable, Optional

POLICIES = ("least_loaded", "warm_affinity", "power_of_two")


class Router:
    def __init__(self, policy: str = "least_loaded",
                 route_fn: Optional[Callable] = None,
                 broker=None, seed: int = 0):
        assert route_fn is not None or policy in POLICIES, policy
        self.policy = policy
        self.route_fn = route_fn
        self.broker = broker
        self._rng = random.Random(seed)
        self.routed: dict[str, int] = {}      # replica -> #assigned
        self.warm_hits = 0
        self.drain_avoided = 0                # times p2c dodged a victim

    def _score(self, rid: str, engines, backlog) -> tuple[int, str]:
        load = engines[rid].load() + (backlog or {}).get(rid, 0)
        return (load, rid)

    def _draining(self, rid: str) -> int:
        """Blocks ``rid`` still owes to open reclaim orders (0 without a
        broker or for brokers without the async order plane)."""
        if self.broker is None:
            return 0
        fn = getattr(self.broker, "open_order_units", None)
        return fn(rid) if fn is not None else 0

    def route(self, req, engines: dict, backlog: Optional[dict] = None
              ) -> str:
        """Pick the replica for ``req``.  ``backlog`` counts routed-but-
        not-yet-submitted invocations per replica (the router's own queue
        view, so bursts don't all land on one replica)."""
        if self.route_fn is not None:
            rid = self.route_fn(req, engines)
        else:
            rid = None
            if self.policy == "warm_affinity":
                warm = [r for r, e in engines.items()
                        if e.warm.get(req.profile.name)]
                if warm:
                    rid = min(warm,
                              key=lambda r: self._score(r, engines, backlog))
                    self.warm_hits += 1
            elif self.policy == "power_of_two":
                ids = sorted(engines)
                pair = ids if len(ids) <= 2 else self._rng.sample(ids, 2)
                rid = min(pair, key=lambda r: (
                    1 if self._draining(r) else 0,
                    self._score(r, engines, backlog)))
                by_load = min(pair,
                              key=lambda r: self._score(r, engines, backlog))
                if rid != by_load:       # the drain tiebreak changed the pick
                    self.drain_avoided += 1
            if rid is None:
                rid = min(engines,
                          key=lambda r: self._score(r, engines, backlog))
        self.routed[rid] = self.routed.get(rid, 0) + 1
        return rid
