"""SLO-tiered multi-tenant scenario bank: named, seeded, deterministic.

The repo's benchmarks drove one trace shape through one tenant; nothing
could detect a regression in fairness, tail behavior, or the
warm/restore/cold balance between PRs.  Following the vHive
snapshot-benchmarking methodology — many workload shapes, ONE comparable
report row each — this module defines a bank of ``FleetSim`` scenarios:

  family      scenarios                      what it stresses
  ---------   ---------------------------    ----------------------------
  diurnal     diurnal_smoke, diurnal_mix     two tenants' day/night peaks
                                             out of phase: one tenant's
                                             peak leans on the slack the
                                             other's trough frees
  fairness    fairness_smoke, fairness_burst one tenant's burst squeezing
                                             another's snapshots — only
                                             down to its sub-budget
  slo         slo_smoke, slo_tiered          latency-tiered traffic under
                                             the ``slo_tiered`` policy:
                                             tight tier spends warm state,
                                             batch routes cold
  scaledown   scaledown_burst                burst -> quiet -> burst:
                                             scale-down under load, then
                                             reclaim orders to re-grow
  hedge       hedged_fleet                   a straggler host: hedged
                                             dispatch fires the backup on
                                             the other host
  mesh        mesh_reclaim                   the scaledown workload on a
                                             4-device host mesh: sharded
                                             replicas, per-device budget
                                             conservation, shard-coherent
                                             reclaim-order drains
  autoscale   autoscale_smoke,               burst -> quiet tail driving
              autoscale_burst, retire_drain  the threshold autoscaler:
                                             hosts boot below the low-
                                             water slack mark, the
                                             emptiest retires after a
                                             quiet streak and DRAINS its
                                             snapshot pool to peers over
                                             the contended interconnect
  dedup       dedup_prefix,                  many functions sharing one
              dedup_baseline                 long common KV prefix:
                                             content-addressed manifests
                                             charge each shared page once
                                             (refcounted, cross-tenant)
                                             and migrations move only
                                             missing pages — vs the
                                             duplicated opaque baseline

Every scenario is a pure function of ``(name, seed)``: arrivals come
from per-tenant ``tracegen`` streams (independent child rngs), replicas
are ``ModelReplica`` — a deterministic modeled twin of ``ServeEngine``
with FIXED virtual costs (no wall-clock measurement anywhere) driving
the real broker/ledger/snapshot/router/fleet stack — so rerunning a
scenario with the same seed is bit-identical, and the bank's rows are a
pinnable regression surface (``benchmarks/run.py --scenarios`` persists
them to ``BENCH_6.json``; CI diffs against the committed baseline).

Each run emits ONE report row with the frozen ``ROW_SCHEMA`` key set —
warm/restore/cold TTFT medians, per-tier TTFT p99, admission-stall p99,
per-tenant squeeze counts, reclaim orders, routes, host-seconds — so a
changed row is a loud diff, not silent drift.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

from repro.cluster.fleet import AutoscalePolicy, FleetScheduler
from repro.cluster.host import HostMemoryBroker
from repro.cluster.router import Router
from repro.cluster.sim import FleetSim
from repro.cluster.topology import DeviceTopology
from repro.launch.distributed import hedged_dispatch
from repro.serving.request import (PROFILES, FunctionProfile, Request,
                                   State, slo_tier_of, tenant_of)
from repro.serving.tracegen import (assign_profiles, bursty_trace,
                                    diurnal_trace)

# every scenario row carries exactly these keys, in this order — the
# golden regression test (tests/test_scenarios.py) pins the set, and
# benchmarks/run.py persists rows in this shape to BENCH_6.json
ROW_SCHEMA = (
    "scenario", "family", "seed", "policy", "hosts", "replicas",
    "tenants", "requests", "completed", "killed",
    "warm_ttft_ms", "restore_ttft_ms", "cold_ttft_ms",
    "ttft_p99_ms_by_tier", "stall_p99_ms",
    "warm_starts", "restore_starts", "remote_restore_starts",
    "cold_starts", "squeezes_by_tenant", "reclaim_orders", "order_units",
    "snapshot_migrations", "host_boots", "host_retires",
    "hedges", "routes", "host_seconds",
    "free_units_end", "device_units_end",
    # content-addressed pool surface (PR 9): units the pools actually
    # CHARGE at end of run (unique pages once) vs what the manifests
    # reference, and the bytes migrations actually moved (missing pages
    # only) — 1.0 / equal-to-referenced for unpaged scenarios
    "unique_snapshot_units", "dedup_ratio", "migrated_snapshot_bytes",
)

# fields holding milliseconds/seconds — the CI regression gate treats
# "new > old * (1 + tolerance)" on any of these as a perf regression
TIME_FIELDS = ("warm_ttft_ms", "restore_ttft_ms", "cold_ttft_ms",
               "stall_p99_ms", "host_seconds")


class ModelReplica:
    """Deterministic modeled twin of ``ServeEngine`` for the scenario
    bank: same broker protocol (grants, order drains, snapshot
    capture/restore, warm keep-alive, scale-down release), but every cost
    is a FIXED virtual-seconds constant — so a scenario's entire schedule
    is a pure function of (trace, seed) and replays bit-identically.

    Interface-compatible with ``FleetSim``/``Router``: ``now`` /
    ``pending`` / ``active`` / ``warm`` / ``done`` / ``load()`` /
    ``host_work()`` / ``_tick()`` / ``metrics()`` plus the start-path
    counters the sim metrics aggregate.  One request row is backed by
    ``devices`` memory units — one KV shard per device of the host mesh
    — so every broker flow is ``rows × devices`` units, order drains go
    one unit per shard in lockstep, and snapshot entries carry one
    fragment per device.  ``devices=1`` (one unit per row) is the exact
    pre-mesh twin, bit-identical trace included."""

    DECODE_S = 1e-3              # one batched decode step
    COLD_S_TOK = 2e-4            # cold prefill, per prompt token
    RESTORE_S = 2e-3             # snapshot copy-back (local)
    CAPTURE_S = 1e-3             # snapshot copy-out on keep-alive expiry
    DRAIN_S = 2.5e-4             # one order-drain chunk (1 unit)
    IDLE_S = 2e-3                # idle clock advance
    KEEPALIVE_S = 0.05           # warm container lifetime
    KILL_AFTER_S = 5.0           # admission deadline (OOM-kill analogue)
    BYTES_PER_TOKEN = 1 << 10    # snapshot payload size basis

    def __init__(self, rid: str, broker: HostMemoryBroker, host_id: str,
                 *, units: int, min_rows: int = 1,
                 tenant: Optional[str] = None, straggle: float = 1.0,
                 devices: int = 1, pager: Optional[Callable] = None):
        assert units >= min_rows >= 1
        assert devices >= 1 and broker.topology.n_devices == devices, \
            f"{rid}: {devices} KV shards on a " \
            f"{broker.topology.n_devices}-device host"
        self.rid = rid
        self.broker = broker
        self.host = host_id
        self.tenant = tenant or ""
        self.straggle = straggle         # work-cost multiplier (hedge scn)
        self.devices = devices           # units (KV shards) per row
        # content-addressed capture: ``pager(prof, toks, devices)`` maps
        # a profile's KV to symbolic page specs (dedup scenarios); the
        # replica tracks which digests it has materialized so a later
        # restore of shared pages is copy-on-write (cheaper)
        self.pager = pager
        self._mapped: set = set()
        self.rows = units
        self.min_rows = min_rows
        self.now = 0.0
        self.pending: deque = deque()
        self.active: dict[str, int] = {}          # req rid -> steps left
        self._active_req: dict[str, Request] = {}
        self.warm: dict[str, list] = {}           # prof -> [(expire, rid, 0)]
        self.done: list[Request] = []
        self.warm_starts = 0
        self.restore_starts = 0
        self.remote_restore_starts = 0
        self.cold_starts = 0
        self.captures = 0
        self.drains = 0
        self.ttft_samples: list[tuple[str, str, str, float]] = []
        self.admit_waits: list[float] = []        # admitted_s - submit_s
        self._prof_tokens: dict[str, int] = {}
        self._orders: deque = deque()
        self._grants: list = []
        broker.register(rid, units * devices, load=self.load,
                        order_sink=self._orders.append, mode="model",
                        tenant=tenant, shards=devices)

    # ----------------------------------------------------------- queries
    def load(self) -> int:
        return len(self.active) + len(self.pending)

    def host_work(self) -> bool:
        return bool(self._orders) or bool(self._grants)

    def _warm_count(self) -> int:
        return sum(len(v) for v in self.warm.values())

    def _free_rows(self) -> int:
        return self.rows - len(self.active) - self._warm_count()

    def predicted_ttft(self, req: Request) -> float:
        """The hedged-dispatch probe: queue depth plus the likely start
        cost, scaled by this replica's straggle factor."""
        start = 0.0 if self.warm.get(req.profile.name) \
            else self.COLD_S_TOK * req.profile.prompt_tokens
        return ((self.load() + 1) * self.DECODE_S + start) * self.straggle

    # -------------------------------------------------------------- tick
    def _tick(self, todo: deque) -> None:
        while todo and todo[0].submit_s <= self.now:
            self.pending.append(todo.popleft())
        # requester side: claim escrowed grant fills; abandon a pending
        # grant whose demand has evaporated
        for g in list(self._grants):
            got = self.broker.claim_grant(g)
            if got:
                assert got % self.devices == 0, (got, self.devices)
                self.rows += got // self.devices
            if not g.done and not (self.pending or self.active):
                self.broker.abandon_grant(g)
            if g.done and g.available == 0 and g.incoherent == 0:
                self._grants.remove(g)
        # victim side: serve one chunk of the front order per tick —
        # free rows first, then the oldest warm container; never shrink
        # below min_rows (cancel the unfulfillable remainder instead)
        while self._orders and not self._orders[0].open:
            self._orders.popleft()
        if self._orders:
            o = self._orders[0]
            if self._free_rows() <= 0 and self._warm_count() > 0 \
                    and self.rows > self.min_rows:
                self._drop_oldest_warm()
            if self._free_rows() > 0 and self.rows > self.min_rows:
                self.now += self.DRAIN_S * self.straggle
                if self.devices == 1:
                    acc = self.broker.fulfill_order(o.order_id, 1)
                else:
                    # one row per tick = one unit per shard, in lockstep
                    # — the coherent stripe the requester can claim grows
                    # by exactly one row once the LAST shard lands
                    acc = sum(self.broker.fulfill_order(o.order_id, 1,
                                                        shard=d)
                              for d in range(self.devices))
                assert acc % self.devices == 0, (acc, self.devices)
                self.rows -= acc // self.devices
                self.drains += 1
            else:
                self.broker.cancel_order(o.order_id)
                self._orders.popleft()
            self.broker.ledger.check()
            return
        admitted = self._try_admit()
        if self.active:
            self._decode()
        elif not admitted:
            self.now += self.IDLE_S
        self._recycle_idle()
        self._request_capacity()
        # the conservation law after EVERY tick; the broker's full
        # structural cross-checks (O(all orders ever issued)) run once
        # per scenario at report time — see _row
        self.broker.ledger.check()

    def _drop_oldest_warm(self) -> None:
        oldest = min(((es[0], prof) for prof, es in self.warm.items()
                      if es), default=None)
        if oldest is not None:
            _, prof = oldest
            self.warm[prof].pop(0)

    # ------------------------------------------------------------- admit
    def _try_admit(self) -> bool:
        admitted = False
        still: deque = deque()
        while self.pending:
            req = self.pending.popleft()
            if req.submit_s > self.now:
                still.append(req)
                continue
            if self.now - req.submit_s > self.KILL_AFTER_S:
                req.state = State.KILLED
                req.done_s = self.now
                self.done.append(req)
                continue
            key = req.profile.name
            self._prof_tokens[key] = req.profile.prompt_tokens
            batch = slo_tier_of(req) == "batch"
            entries = None if batch else self.warm.get(key)
            if entries:
                entries.pop()                 # adopt the newest container
                self._start(req, "warm", 0.0)
                admitted = True
                continue
            if self._free_rows() <= 0:
                still.append(req)
                continue
            snap = self.broker.snapshot_lookup(key) \
                if not batch and self.broker.snapshot_restorable(key) \
                else None
            if snap is not None:
                owed = snap.claim_copy()      # first remote restore pays
                cost = self.RESTORE_S
                if getattr(snap, "pages", None) is not None:
                    # CoW restore: already-materialized pages remap for
                    # free; the floor keeps restore strictly above warm
                    specs = self.broker.snapshot_page_specs(key)
                    new = sum(1 for d, _u, _b, _p in specs
                              if d not in self._mapped)
                    cost *= max(new / len(specs), 0.25)
                    self._mapped.update(d for d, _u, _b, _p in specs)
                path = "remote_restore" if owed > 0.0 else "restore"
                self._start(req, path, cost + owed)
            else:
                self._start(req, "cold",
                            self.COLD_S_TOK * req.profile.prompt_tokens)
            admitted = True
        self.pending = still
        return admitted

    def _start(self, req: Request, path: str, cost: float) -> None:
        self.now += cost * self.straggle
        req.admitted_s = self.now
        self.admit_waits.append(self.now - req.submit_s)
        req.state = State.RUNNING
        self.active[req.rid] = req.profile.decode_tokens
        self._active_req[req.rid] = req
        setattr(req, "_start_path", path)
        if path == "warm":
            self.warm_starts += 1
        elif path == "restore":
            self.restore_starts += 1
        elif path == "remote_restore":
            self.remote_restore_starts += 1
        else:
            self.cold_starts += 1

    # ------------------------------------------------------------ decode
    def _decode(self) -> None:
        self.now += self.DECODE_S * self.straggle
        for rid in list(self.active):
            self.active[rid] -= 1
            req = self._active_req[rid]
            if req.first_token_s is None:
                req.first_token_s = self.now
                self.ttft_samples.append(
                    (getattr(req, "_start_path", "cold"),
                     slo_tier_of(req), tenant_of(req) or "default",
                     req.first_token_s - req.submit_s))
            if self.active[rid] <= 0:
                del self.active[rid]
                del self._active_req[rid]
                req.state = State.DONE
                req.done_s = self.now
                self.done.append(req)
                # batch rows go straight back free — batch traffic must
                # not mint the warm capacity the tight tier depends on
                if slo_tier_of(req) != "batch":
                    self.warm.setdefault(req.profile.name, []).append(
                        (self.now + self.KEEPALIVE_S, req.rid, 0))

    # --------------------------------------------------- keep-alive pool
    def _recycle_idle(self) -> None:
        for prof, entries in list(self.warm.items()):
            fresh = []
            for t, rid, row in entries:
                if t <= self.now:
                    self._capture(prof)       # snapshot before recycling
                else:
                    fresh.append((t, rid, row))
            self.warm[prof] = fresh
        # scale-down: release rows above live demand (never below
        # min_rows) — the squeezed-VM behavior the broker re-grows later
        keep = max(self.min_rows, len(self.active) + self._warm_count()
                   + len(self.pending))
        release = self.rows - keep
        if release > 0:
            self.broker.release_units(self.rid, release * self.devices)
            self.rows -= release

    def _capture(self, prof: str) -> None:
        if self.broker.snapshot_available(prof):
            return
        toks = self._prof_tokens.get(prof, 0)
        # sharded KV: one fragment per device (all present — a partial
        # capture would be unrestorable and is never offered to the pool)
        frags = tuple(("kv", prof, d) for d in range(self.devices)) \
            if self.devices > 1 else None
        pages = self.pager(prof, toks, self.devices) if self.pager \
            else None
        if self.broker.snapshot_put(prof, units=self.devices,
                                    payload=("kv", prof),
                                    tokens=toks,
                                    nbytes=toks * self.BYTES_PER_TOKEN,
                                    replica_id=self.rid,
                                    tenant=self.tenant, fragments=frags,
                                    pages=pages):
            if pages is not None:
                self._mapped.update(d for d, _u, _b, _p in pages)
            self.captures += 1
            self.now += self.CAPTURE_S * self.straggle

    # ---------------------------------------------------------- capacity
    def _request_capacity(self) -> None:
        if self._orders:
            return                  # mid-drain: don't tug both directions
        ready = sum(1 for r in self.pending if r.submit_s <= self.now)
        # outstanding is in UNITS (incoherent shard fills included — they
        # are still owed to us); demand is in rows
        outstanding = sum(g.pending + g.available + g.incoherent
                          for g in self._grants)
        want = ready - self._free_rows() - outstanding // self.devices
        if want > 0:
            g = self.broker.request_grant(self.rid, want * self.devices)
            assert g.granted % self.devices == 0, g
            self.rows += g.granted // self.devices
            if not g.done or g.available or g.incoherent:
                self._grants.append(g)

    # ----------------------------------------------------------- metrics
    def metrics(self) -> dict[str, Any]:
        return {"reclaimed_bytes": 0, "migrated_bytes": 0,
                "reclaim_events": self.drains}


class HedgedRoutePolicy:
    """Router ``route_fn`` built on the seed's ``hedged_dispatch``
    contract: submit to the least-loaded replica's predicted TTFT, hedge
    to the second if it misses ``deadline_s``, and route the request to
    the LAST chosen replica (the backup when the hedge fired) — so
    exactly one replica runs it and exactly one result is charged."""

    def __init__(self, deadline_s: float):
        self.deadline_s = deadline_s
        self.hedges = 0
        self.chosen_log: list[tuple[str, list[str]]] = []

    def __call__(self, req: Request, engines: dict) -> str:
        ids = sorted(engines)
        reps = [engines[r] for r in ids]
        chosen = hedged_dispatch(
            reps, lambda i: reps[i].predicted_ttft(req),
            deadline_s=self.deadline_s)
        if len(chosen) > 1:
            self.hedges += 1
        self.chosen_log.append((req.rid, [ids[i] for i in chosen]))
        return ids[chosen[-1]]


# --------------------------------------------------------------- builders
def _tenant_profiles(tenant: str, names: tuple[str, ...],
                     tiers: Optional[dict[str, str]] = None
                     ) -> dict[str, FunctionProfile]:
    """Tenant-namespaced copies of the paper profiles: snapshot keys and
    warm pools become per-tenant automatically."""
    out = {}
    for n in names:
        p = PROFILES[n]
        out[f"{tenant}/{n}"] = dataclasses.replace(
            p, name=f"{tenant}/{n}", tenant=tenant,
            slo_tier=(tiers or {}).get(n, "standard"))
    return out


def _requests(streams: list[tuple[str, list]]) -> list[Request]:
    """Merge per-stream ``(submit_s, profile)`` lists into one arrival
    order (ties break on stream name, then index — deterministic)."""
    reqs = []
    for stream, assigned in streams:
        for i, (t, prof) in enumerate(assigned):
            reqs.append(Request(rid=f"{stream}-{i}", profile=prof,
                                submit_s=t))
    reqs.sort(key=lambda r: (r.submit_s, r.rid))
    return reqs


def _build(hosts: dict[str, list], *, budget: int, pool_units: int,
           tenants: Optional[dict[str, int]] = None,
           policy: str = "drain_weighted", seed: int = 0,
           route_fn: Optional[Callable] = None, devices: int = 1,
           pager: Optional[Callable] = None):
    """One broker per host (shared tenant sub-budget split), replicas
    placed per spec, router wired to the fleet scheduler.  ``hosts``:
    host id -> list of (rid, units, tenant, straggle, min_rows).

    ``budget`` / ``pool_units`` / tenant sub-budgets are in ROWS; with
    ``devices > 1`` every row is ``devices`` units (one KV shard per
    device), so each host gets a uniform ``DeviceTopology`` of
    ``budget × devices`` total units and all ledger flows stripe over
    the mesh.  ``devices=1`` builds the exact legacy scalar broker."""
    topo = None if devices == 1 \
        else DeviceTopology.uniform(budget * devices, devices)
    sched = FleetScheduler()
    engines: dict[str, dict[str, ModelReplica]] = {}
    for h, reps in hosts.items():
        b = HostMemoryBroker(
            budget if devices == 1 else None, async_reclaim=True,
            snapshot_pool_units=(pool_units * devices
                                 if pool_units else pool_units),
            tenants={t: v * devices for t, v in tenants.items()}
            if tenants else None,
            topology=topo)
        sched.add_host(h, b)
        engines[h] = {rid: ModelReplica(rid, b, h, units=units,
                                        tenant=tenant, straggle=straggle,
                                        min_rows=min_rows,
                                        devices=devices, pager=pager)
                      for rid, units, tenant, straggle, min_rows in reps}
    router = Router(policy=policy, seed=seed, route_fn=route_fn,
                    fleet=sched)
    sim = FleetSim(engines, router, scheduler=sched)
    return sim, sched


def _preseed_snapshots(sched: FleetScheduler, profs: dict, *,
                       host: Optional[str] = None,
                       pager: Optional[Callable] = None) -> None:
    """Seed the pool with restorable snapshots for ``profs`` (first host
    by default): the deterministic stand-in for a previous epoch's
    captures — fairness scenarios start with protected warm state, SLO
    scenarios give the tight tier a restore path from arrival one.
    ``pager`` preseeds content-addressed manifests instead of opaque
    payloads (the dedup family)."""
    h = host if host is not None else sorted(sched.brokers)[0]
    b = sched.brokers[h]
    for name, p in sorted(profs.items()):
        ok = b.snapshot_put(name, units=1, payload=("kv", name),
                            tokens=p.prompt_tokens,
                            nbytes=p.prompt_tokens
                            * ModelReplica.BYTES_PER_TOKEN,
                            tenant=p.tenant,
                            pages=pager(name, p.prompt_tokens, 1)
                            if pager else None)
        assert ok, f"preseed snapshot for {name} did not fit"


# common-prefix KV model for the dedup family: every function's prompt
# opens with the same ``_COMMON_TOK``-token system preamble (two shared
# pages — only the first carries the entry's unit charge, so the page
# sum still equals the manifest's units), and the function-specific tail
# rides a per-profile page with the remaining bytes.  Digests are
# symbolic (content IS identity here), parameterized by the device count
# so a sharded variant never collides with the flat one.
_COMMON_TOK = 6          # <= the smallest profile prompt (html: 8)


def _prefix_pager(prof: str, toks: int, devices: int) -> list:
    assert toks >= _COMMON_TOK, (prof, toks)
    bpt = ModelReplica.BYTES_PER_TOKEN
    half = _COMMON_TOK * bpt // 2
    return [(f"pfx0.d{devices}", devices, half, ("pg", "pfx", 0)),
            (f"pfx1.d{devices}", 0, half, ("pg", "pfx", 1)),
            (f"tail.{prof}", 0, (toks - _COMMON_TOK) * bpt,
             ("pg", "tail", prof))]


# ------------------------------------------------------------ report row
def _p(values: list[float], q: float) -> Optional[float]:
    return round(float(np.percentile(values, q)), 6) if values else None


def _ms(values: list[float], q: float) -> Optional[float]:
    vals = [v * 1e3 for v in values]
    return _p(vals, q)


def _row(name: str, family: str, seed: int, policy: str, sim: FleetSim,
         sched: FleetScheduler, requests: list[Request],
         hedges: int = 0) -> dict[str, Any]:
    m = sim.metrics()
    assert m["truncated"] is False, \
        f"{name}: run exhausted max_ticks with work outstanding"
    samples = [s for e in sim.engines.values() for s in e.ttft_samples]
    waits = [w for e in sim.engines.values() for w in e.admit_waits]

    def path_ms(paths: tuple[str, ...]) -> Optional[float]:
        return _ms([t for p, _, _, t in samples if p in paths], 50)

    tiers = sorted({tier for _, tier, _, _ in samples})
    by_tier = {tier: _ms([t for _, tr, _, t in samples if tr == tier], 99)
               for tier in tiers}
    squeezes: dict[str, int] = {}
    orders = 0
    order_units = 0
    free_end = {}
    device_end = {}
    unique_end = 0
    referenced_end = 0
    # retired hosts leave sched.brokers but their (emptied) brokers stay
    # on the sim — fold them back in so squeeze/order accounting covers
    # the whole run and conservation is visible end-to-end
    brokers = {**getattr(sim, "_brokers", {}), **sched.brokers}
    for h in sorted(brokers):
        b = brokers[h]
        b.check_invariants()       # full structural pass, end of run
        for rec in b.squeeze_log:
            squeezes[rec.tenant] = squeezes.get(rec.tenant, 0) + 1
        orders += len(b.orders)
        order_units += sum(o.units for o in b.orders.values())
        free_end[h] = b.free_units
        device_end[h] = [b.ledger.free_dev(d)
                         for d in range(b.ledger.n_devices)]
        unique_end += b.snapshot_units()
        referenced_end += b.snapshots.referenced_units \
            if b.snapshots is not None else 0
    row = {
        "scenario": name,
        "family": family,
        "seed": seed,
        "policy": policy,
        "hosts": len(brokers),
        "replicas": len(sim.engines),
        "tenants": sorted({tenant_of(r) or "default" for r in requests}),
        "requests": len(requests),
        "completed": m["completed"],
        "killed": m["killed"],
        "warm_ttft_ms": path_ms(("warm",)),
        "restore_ttft_ms": path_ms(("restore", "remote_restore")),
        "cold_ttft_ms": path_ms(("cold",)),
        "ttft_p99_ms_by_tier": by_tier,
        "stall_p99_ms": _ms(waits, 99),
        "warm_starts": m["warm_hits"],
        "restore_starts": m["restore_starts"],
        "remote_restore_starts": m["remote_restore_starts"],
        "cold_starts": m["cold_starts"],
        "squeezes_by_tenant": {t: squeezes[t] for t in sorted(squeezes)},
        "reclaim_orders": orders,
        "order_units": order_units,
        "snapshot_migrations": m["snapshot_migrations"],
        "host_boots": sched.host_boots,
        "host_retires": sched.host_retires,
        "hedges": hedges,
        "routes": {r: m["routed"][r] for r in sorted(m["routed"])},
        "host_seconds": round(sim.virtual_now(), 9),
        "free_units_end": free_end,
        "device_units_end": device_end,
        "unique_snapshot_units": unique_end,
        "dedup_ratio": round(unique_end / referenced_end, 6)
        if referenced_end else 1.0,
        "migrated_snapshot_bytes": sum(r.nbytes for r in sched.migrations),
    }
    assert tuple(row) == ROW_SCHEMA
    return row


# ------------------------------------------------------------- scenarios
def _scn_diurnal(name: str, seed: int, *, n_hosts: int,
                 duration_s: float, rate: float,
                 policy: str = "drain_weighted") -> dict[str, Any]:
    """Two tenants with opposite-phase diurnal load on a shared fleet:
    acme peaks while beta troughs, so the broker keeps re-carving the
    same budget between them (grants out of the trough tenant's released
    rows, squeezes of its expired-warm snapshots down to its
    sub-budget).  The multi-host variant routes by load alone, so a
    tenant's arrivals land on hosts that never captured its snapshots —
    exercising cross-host snapshot migration."""
    tenants = {"acme": 5, "beta": 4}
    profs = {t: _tenant_profiles(t, ("cnn", "html"))
             for t in tenants}
    hosts = {f"h{i}": [(f"h{i}/acme0", 2, "acme", 1.0, 1),
                       (f"h{i}/beta0", 2, "beta", 1.0, 1)]
             for i in range(n_hosts)}
    sim, sched = _build(hosts, budget=9, pool_units=4, tenants=tenants,
                        policy=policy, seed=seed)
    streams = []
    for i, t in enumerate(sorted(tenants)):
        arr = diurnal_trace(duration_s, rate, period_s=duration_s,
                            depth=0.8, phase=i * np.pi, seed=seed,
                            stream=t)
        streams.append((t, assign_profiles(arr, profs[t], seed=seed,
                                           stream=t)))
    reqs = _requests(streams)
    sim.run(list(reqs))
    return _row(name, "diurnal", seed, policy, sim, sched, reqs)


def _scn_fairness(name: str, seed: int, *, duration_s: float,
                  burst_x: float) -> dict[str, Any]:
    """A burst tenant's grants squeeze the pool — but the steady tenant's
    pre-seeded snapshots are protected below its sub-budget, so the
    squeeze log shows the burst tenant eating its OWN cache first and
    only skimming the steady tenant's surplus."""
    tenants = {"steady": 4, "burst": 8}
    steady_profs = _tenant_profiles("steady", ("cnn", "html", "bfs"))
    burst_profs = _tenant_profiles("burst", ("bert",))
    hosts = {"h0": [("h0/steady0", 2, "steady", 1.0, 1),
                    ("h0/burst0", 2, "burst", 1.0, 1),
                    ("h0/burst1", 2, "burst", 1.0, 1)]}
    sim, sched = _build(hosts, budget=12, pool_units=4, tenants=tenants,
                        policy="drain_weighted", seed=seed)
    # steady enters with a full cache (3 entries): its usage (2 granted
    # + 3 snapshot) sits ONE unit above its sub-budget of 4, so exactly
    # one entry is squeeze-eligible and two stay protected
    _preseed_snapshots(sched, steady_profs)
    streams = [
        ("steady", assign_profiles(
            bursty_trace(duration_s, 30.0, burst_x=1.0, seed=seed,
                         stream="steady"),
            steady_profs, seed=seed, stream="steady")),
        ("burst", assign_profiles(
            bursty_trace(duration_s, 30.0, burst_x=burst_x,
                         burst_at=(duration_s * 0.25,),
                         burst_len=duration_s * 0.5, seed=seed,
                         stream="burst"),
            burst_profs, seed=seed, stream="burst")),
    ]
    reqs = _requests(streams)
    sim.run(list(reqs))
    return _row(name, "fairness", seed, "drain_weighted", sim, sched, reqs)


def _scn_slo(name: str, seed: int, *, duration_s: float,
             rate: float) -> dict[str, Any]:
    """Latency-tiered traffic under ``slo_tiered``: the tight tier spends
    warm/snapshot capacity (pre-seeded restore path from arrival one),
    the batch tier routes and starts cold.  The acceptance bar: tight
    TTFT p99 < batch TTFT p99."""
    tight = _tenant_profiles("svc", ("cnn", "html"),
                             tiers={"cnn": "tight", "html": "tight"})
    batch = _tenant_profiles("svc", ("bfs", "bert"),
                             tiers={"bfs": "batch", "bert": "batch"})
    profs = {**tight, **batch}
    hosts = {"h0": [("h0/r0", 3, "svc", 1.0, 1),
                    ("h0/r1", 3, "svc", 1.0, 1),
                    ("h0/r2", 3, "svc", 1.0, 1)]}
    sim, sched = _build(hosts, budget=13, pool_units=4,
                        tenants={"svc": 13}, policy="slo_tiered",
                        seed=seed)
    _preseed_snapshots(sched, tight)
    streams = [("svc", assign_profiles(
        bursty_trace(duration_s, rate, burst_x=1.0, seed=seed,
                     stream="svc"),
        profs, seed=seed, stream="svc"))]
    reqs = _requests(streams)
    sim.run(list(reqs))
    return _row(name, "slo", seed, "slo_tiered", sim, sched, reqs)


def _scn_scaledown(name: str, seed: int) -> dict[str, Any]:
    """Burst -> quiet -> burst on one host: the quiet phase scale-downs
    (keep-alive expiry, snapshot capture, row release), the second burst
    re-grows through grants and reclaim orders against the shrunk fleet."""
    profs = _tenant_profiles("app", ("cnn", "bfs", "html"))
    hosts = {"h0": [("h0/r0", 3, None, 1.0, 1),
                    ("h0/r1", 3, None, 1.0, 1)]}
    sim, sched = _build(hosts, budget=10, pool_units=3,
                        tenants=None, policy="drain_weighted", seed=seed)
    arr = bursty_trace(2.0, 30.0, burst_x=5.0, burst_at=(0.0, 1.25),
                       burst_len=0.35, quiet_after=1.7, seed=seed,
                       stream="app")
    reqs = _requests([("app", assign_profiles(arr, profs, seed=seed,
                                              stream="app"))])
    sim.run(list(reqs))
    return _row(name, "scaledown", seed, "drain_weighted", sim, sched,
                reqs)


def _scn_mesh_reclaim(name: str, seed: int, *,
                      devices: int = 4) -> dict[str, Any]:
    """The scaledown workload on a ``devices``-device host mesh: every
    replica's KV stripes one shard per device, grants/releases are
    balanced unit vectors, reclaim orders drain one unit per shard in
    lockstep (shard-coherent: the requester's claimable stripe grows
    only when the LAST shard lands), and snapshot entries carry one
    fragment per device.  Per-device conservation is checked by the
    ledger after every tick; ``device_units_end`` pins the final
    per-device free vectors in the baseline."""
    profs = _tenant_profiles("app", ("cnn", "bfs", "html"))
    hosts = {"h0": [("h0/r0", 3, None, 1.0, 1),
                    ("h0/r1", 3, None, 1.0, 1)]}
    sim, sched = _build(hosts, budget=10, pool_units=3,
                        tenants=None, policy="drain_weighted", seed=seed,
                        devices=devices)
    arr = bursty_trace(2.0, 30.0, burst_x=5.0, burst_at=(0.0, 1.25),
                       burst_len=0.35, quiet_after=1.7, seed=seed,
                       stream="app")
    reqs = _requests([("app", assign_profiles(arr, profs, seed=seed,
                                              stream="app"))])
    sim.run(list(reqs))
    return _row(name, "mesh", seed, "drain_weighted", sim, sched, reqs)


def _scn_dedup(name: str, seed: int, *, paged: bool, duration_s: float,
               rate: float) -> dict[str, Any]:
    """Two tenants' function sets all sharing the ``_prefix_pager``
    common preamble, on two hosts with load-only routing (so arrivals
    keep landing on hosts that never captured the snapshot — exercising
    cross-host migration).  ``paged=True`` stores content-addressed
    manifests: the pools charge each shared prefix page ONCE (unique
    units well below the referenced total, cross-tenant — the first
    dropped owner reattributes, never strands, its charge) and a
    migration moves only pages the destination store lacks.
    ``paged=False`` is the duplicated baseline the acceptance criteria
    compare against: same trace, every entry opaque and full-price."""
    tenants = {"acme": 5, "beta": 4}
    profs = {t: _tenant_profiles(t, ("cnn", "html")) for t in tenants}
    hosts = {f"h{i}": [(f"h{i}/acme0", 2, "acme", 1.0, 1),
                       (f"h{i}/beta0", 2, "beta", 1.0, 1)]
             for i in range(2)}
    pager = _prefix_pager if paged else None
    sim, sched = _build(hosts, budget=9, pool_units=4, tenants=tenants,
                        policy="least_loaded", seed=seed, pager=pager)
    allp: dict[str, FunctionProfile] = {}
    for t in sorted(profs):
        allp.update(profs[t])
    _preseed_snapshots(sched, allp, pager=pager)
    streams = []
    for i, t in enumerate(sorted(tenants)):
        arr = diurnal_trace(duration_s, rate, period_s=duration_s,
                            depth=0.8, phase=i * np.pi, seed=seed,
                            stream=t)
        streams.append((t, assign_profiles(arr, profs[t], seed=seed,
                                           stream=t)))
    reqs = _requests(streams)
    sim.run(list(reqs))
    return _row(name, "dedup", seed, "least_loaded", sim, sched, reqs)


def _scn_hedged(name: str, seed: int) -> dict[str, Any]:
    """Two hosts, one a straggler (every virtual cost x40): hedged
    dispatch predicts the primary misses the deadline and fires the
    backup on the OTHER host — each request still runs on exactly one
    replica, so exactly one result is charged."""
    profs = _tenant_profiles("app", ("cnn", "html"))
    hosts = {"hA": [("hA/r0", 3, None, 40.0, 1)],      # the straggler
             "hB": [("hB/r0", 3, None, 1.0, 1)]}
    policy = HedgedRoutePolicy(deadline_s=0.02)
    sim, sched = _build(hosts, budget=8, pool_units=2, tenants=None,
                        seed=seed, route_fn=policy)
    arr = bursty_trace(0.5, 60.0, burst_x=2.0, seed=seed, stream="app")
    reqs = _requests([("app", assign_profiles(arr, profs, seed=seed,
                                              stream="app"))])
    sim.run(list(reqs))
    row = _row(name, "hedge", seed, "hedged", sim, sched, reqs,
               hedges=policy.hedges)
    return row


def _replica_factory(*, budget: int, pool_units: int, units: int,
                     min_rows: int = 1,
                     tenants: Optional[dict[str, int]] = None,
                     tenant: Optional[str] = None) -> Callable:
    """Host factory for the autoscaler: a fresh async broker with the
    same budget/pool shape as the starting fleet, one replica registered
    at construction.  ``clock`` is a frozen zero until the sim re-stamps
    it with the host's virtual timebase — a boot never reads wall time,
    so autoscaled runs stay bit-deterministic."""
    def factory(host_id: str):
        b = HostMemoryBroker(budget, async_reclaim=True,
                             snapshot_pool_units=pool_units,
                             tenants=dict(tenants) if tenants else None,
                             clock=lambda: 0.0)
        rid = f"{host_id}/r0"
        return b, {rid: ModelReplica(rid, b, host_id, units=units,
                                     min_rows=min_rows, tenant=tenant)}
    return factory


def _scn_autoscale(name: str, seed: int, *, duration_s: float,
                   rate: float, burst_x: float, low_water: int,
                   high_water: int, quiet_ticks: int,
                   max_hosts: int) -> dict[str, Any]:
    """One starting host under a burst: grant demand eats the fleet's
    free-unit slack through the low-water mark, so the autoscaler boots
    hosts (up to ``max_hosts``); the quiet tail releases rows back,
    slack holds at/above the high-water mark for a sustained streak,
    and the emptiest host retires — draining its captured snapshots to
    the survivors over the contended interconnect."""
    profs = _tenant_profiles("app", ("cnn", "html"))
    hosts = {"h0": [("h0/r0", 2, None, 1.0, 1)]}
    sim, sched = _build(hosts, budget=8, pool_units=3, tenants=None,
                        policy="drain_weighted", seed=seed)
    sim.set_autoscaler(
        AutoscalePolicy(low_water=low_water, high_water=high_water,
                        quiet_ticks=quiet_ticks, min_hosts=1,
                        max_hosts=max_hosts),
        _replica_factory(budget=8, pool_units=3, units=2))
    arr = bursty_trace(duration_s, rate, burst_x=burst_x,
                       burst_at=(duration_s * 0.1,),
                       burst_len=duration_s * 0.4,
                       quiet_after=duration_s * 0.7, seed=seed,
                       stream="app")
    reqs = _requests([("app", assign_profiles(arr, profs, seed=seed,
                                              stream="app"))])
    sim.run(list(reqs))
    assert sched.host_boots >= 1, \
        f"{name}: the burst never tripped the low-water mark"
    return _row(name, "autoscale", seed, "drain_weighted", sim, sched,
                reqs)


def _scn_retire_drain(name: str, seed: int) -> dict[str, Any]:
    """Drain-via-migration, deterministic by construction: every request
    is pinned to h0 (whose replica holds 6 of 10 rows, so h0's free
    units can never exceed 4), while idle h1 sits at a constant 6 free
    units with two preseeded restorable snapshots the trace never
    requests.  The quiet streak is therefore always accumulating, h1 is
    PROVABLY the emptiest host when it trips, and h0 is guaranteed room
    (>= 2 free units, pool 2 captures + 2 migrations <= cap 4).
    Acceptance: h1 retires mid-run, every restorable entry it held
    lands on h0 (migrated, NOT discarded), and per-host conservation
    holds after every lifecycle event."""
    tenants = {"app": 10}
    profs = _tenant_profiles("app", ("cnn", "html"))
    # preseed-only keys: profiles the trace never requests, so they sit
    # untouched in h1's pool until the drain moves them
    cold = _tenant_profiles("app", ("bfs", "bert"))
    hosts = {"h0": [("h0/r0", 6, "app", 1.0, 6)],   # 6 pinned rows
             "h1": [("h1/r0", 2, "app", 1.0, 1)]}
    sim, sched = _build(hosts, budget=10, pool_units=4, tenants=tenants,
                        policy="drain_weighted", seed=seed,
                        route_fn=lambda req, engines: "h0/r0")
    _preseed_snapshots(sched, cold, host="h1")
    sim.set_autoscaler(
        # low_water=0: slack can never go negative, so no boots — this
        # scenario isolates the retire/drain half of the lifecycle;
        # slack = h0 (2..4) + h1 (6) >= high_water always, so the streak
        # trips at exactly eval ``quiet_ticks``
        AutoscalePolicy(low_water=0, high_water=8, quiet_ticks=60,
                        min_hosts=1, max_hosts=2),
        _replica_factory(budget=10, pool_units=4, units=2,
                         tenants=tenants, tenant="app"))
    arr = bursty_trace(0.6, 50.0, burst_x=3.0, burst_at=(0.05,),
                       burst_len=0.2, seed=seed, stream="app")
    reqs = _requests([("app", assign_profiles(arr, profs, seed=seed,
                                              stream="app"))])
    sim.run(list(reqs))
    assert sched.host_retires == 1 and "h1" in sched.retired, \
        f"{name}: h1 did not retire (retired={sorted(sched.retired)})"
    assert sched.drain_discarded == 0, \
        f"{name}: drain discarded {sched.drain_discarded} snapshots"
    for key in sorted(cold):
        assert sched.brokers["h0"].snapshot_restorable(key), \
            f"{name}: preseeded snapshot {key!r} was not migrated to h0"
    return _row(name, "autoscale", seed, "pinned", sim, sched, reqs)


# ------------------------------------------------------------- registry
SCENARIOS: dict[str, tuple[str, Callable[[int], dict[str, Any]]]] = {
    "diurnal_smoke": ("diurnal", lambda s: _scn_diurnal(
        "diurnal_smoke", s, n_hosts=1, duration_s=0.5, rate=80.0)),
    "diurnal_mix": ("diurnal", lambda s: _scn_diurnal(
        "diurnal_mix", s, n_hosts=2, duration_s=1.0, rate=120.0,
        policy="least_loaded")),
    "fairness_smoke": ("fairness", lambda s: _scn_fairness(
        "fairness_smoke", s, duration_s=0.5, burst_x=4.0)),
    "fairness_burst": ("fairness", lambda s: _scn_fairness(
        "fairness_burst", s, duration_s=1.25, burst_x=6.0)),
    "slo_smoke": ("slo", lambda s: _scn_slo(
        "slo_smoke", s, duration_s=0.5, rate=100.0)),
    "slo_tiered": ("slo", lambda s: _scn_slo(
        "slo_tiered", s, duration_s=1.5, rate=150.0)),
    "scaledown_burst": ("scaledown", lambda s: _scn_scaledown(
        "scaledown_burst", s)),
    "hedged_fleet": ("hedge", lambda s: _scn_hedged("hedged_fleet", s)),
    "mesh_reclaim": ("mesh", lambda s: _scn_mesh_reclaim(
        "mesh_reclaim", s)),
    "autoscale_smoke": ("autoscale", lambda s: _scn_autoscale(
        "autoscale_smoke", s, duration_s=0.8, rate=100.0, burst_x=5.0,
        low_water=4, high_water=12, quiet_ticks=60, max_hosts=3)),
    "autoscale_burst": ("autoscale", lambda s: _scn_autoscale(
        "autoscale_burst", s, duration_s=1.5, rate=140.0, burst_x=6.0,
        low_water=4, high_water=12, quiet_ticks=60, max_hosts=3)),
    "retire_drain": ("autoscale", lambda s: _scn_retire_drain(
        "retire_drain", s)),
    "dedup_prefix": ("dedup", lambda s: _scn_dedup(
        "dedup_prefix", s, paged=True, duration_s=0.8, rate=100.0)),
    "dedup_baseline": ("dedup", lambda s: _scn_dedup(
        "dedup_baseline", s, paged=False, duration_s=0.8, rate=100.0)),
}

# the smallest scenario per family — the CI fast tier's smoke set
SMOKE = ("diurnal_smoke", "fairness_smoke", "slo_smoke",
         "scaledown_burst", "hedged_fleet", "mesh_reclaim",
         "autoscale_smoke", "dedup_prefix")


def run_scenario(name: str, seed: int = 0) -> dict[str, Any]:
    """Run one bank entry; the returned row carries exactly
    ``ROW_SCHEMA``'s keys and is bit-identical for a fixed seed."""
    assert name in SCENARIOS, \
        f"unknown scenario {name!r} (have {sorted(SCENARIOS)})"
    family, fn = SCENARIOS[name]
    row = fn(seed)
    assert tuple(row) == ROW_SCHEMA and row["family"] == family
    return row


def run_bank(names: Optional[list[str]] = None, seed: int = 0
             ) -> dict[str, dict[str, Any]]:
    """Run (a subset of) the bank; rows keyed by scenario name."""
    return {n: run_scenario(n, seed) for n in (names or sorted(SCENARIOS))}
