"""Fleet layer: N hosts, one scheduler — placement + warm-state migration.

One ``HostMemoryBroker`` arbitrates ONE host's budget; the fleet
scheduler is the level above (the ROADMAP's multi-host item): it owns a
broker per host, places replicas onto hosts, and moves warm-restart
state *between* hosts, TrEnv-X-style — a host that never ran a function
can still restore its prefix KV from a peer's snapshot pool instead of
paying a cold prefill.

Placement (``place``) is capacity-driven and deterministic:

  * ``spread`` — put the replica on the host with the most reclaimable
    capacity (free pool + droppable snapshot charge); classic load
    spreading, maximizes per-host slack.
  * ``pack``   — best-fit: the fitting host with the LEAST capacity, so
    big contiguous budgets stay available for later replicas.

Ties break on host id; a replica that fits nowhere is a placement error
(the caller sees it immediately, not as a later register failure).

Cross-host snapshot migration (``ensure_local`` / ``migrate_snapshot``):
when the destination host lacks a restorable snapshot for a function but
a peer holds one, the scheduler debits the peer's pool (its ledger
credits the units back to its free pool), charges a modeled inter-host
copy — REAL payload bytes over a configurable ``bandwidth_bytes_per_s``
plus a fixed ``link_latency_s`` — and credits the destination pool.  The
copy wall rides the migrated ``Snapshot`` (``copy_seconds``) and is paid
by the first restore that uses it (``ServeEngine._start_restore`` tags
that event ``source="remote"``), so a remote restore lands strictly
between a local restore and a cold prefill.  Unit conservation stays
per-host throughout: a migration is ``snapshot_drop`` on the source
ledger and ``snapshot_put`` on the destination ledger — units never
teleport between budgets, and ``check_invariants`` proves every host's
``free + granted + escrow + snapshot == budget`` after every fleet
event.

A migration is refused (returns ``None``, nothing mutated) when no peer
holds a restorable copy or the destination pool has no room — the
destination then simply cold-starts, exactly as before the fleet
existed.

``FleetSim`` (``repro.cluster.sim``) drives N hosts of engines on one
deterministic virtual timebase and calls ``ensure_local`` as arrivals
are routed; ``Router``'s ``drain_weighted`` policy consumes the fleet
view (``host_of`` / ``snapshot_host`` / ``open_order_units``) for its
placement tiers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.cluster.host import HostMemoryBroker

PLACEMENTS = ("spread", "pack")


@dataclasses.dataclass
class MigrationRecord:
    """One cross-host snapshot migration: ``key``'s warm state moved from
    ``src`` to ``dst``, paying a modeled ``copy_seconds`` transfer for
    ``nbytes`` real payload bytes."""
    key: str
    src: str
    dst: str
    units: int
    nbytes: int
    copy_seconds: float
    at: float                    # fleet-clock timestamp


class FleetScheduler:
    """Owns one ``HostMemoryBroker`` per host: places replicas, serves
    the fleet-wide snapshot view, and migrates warm state across hosts."""

    def __init__(self, *, bandwidth_bytes_per_s: float = float(1 << 30),
                 link_latency_s: float = 5e-4,
                 clock: Optional[Callable[[], float]] = None):
        assert bandwidth_bytes_per_s > 0 and link_latency_s >= 0
        self.bandwidth_bytes_per_s = bandwidth_bytes_per_s
        self.link_latency_s = link_latency_s
        self._clock = clock if clock is not None else (lambda: 0.0)
        self.brokers: dict[str, HostMemoryBroker] = {}
        self.placements: dict[str, str] = {}     # replica -> host
        self.migrations: list[MigrationRecord] = []
        self.migration_denied = 0    # no source / no room at destination

    def set_clock(self, clock: Callable[[], float]) -> None:
        """Inject the fleet's deterministic timebase (``FleetSim`` passes
        the sum of every host's virtual clock)."""
        self._clock = clock

    # ------------------------------------------------------------ topology
    def add_host(self, host_id: str, broker: HostMemoryBroker) -> None:
        assert host_id not in self.brokers, host_id
        self.brokers[host_id] = broker

    def host_of(self, replica_id: str) -> Optional[str]:
        return self.placements.get(replica_id)

    def broker_of(self, replica_id: str) -> Optional[HostMemoryBroker]:
        host = self.placements.get(replica_id)
        return self.brokers.get(host) if host is not None else None

    # ----------------------------------------------------------- placement
    def capacity(self, host_id: str) -> int:
        """Units a new replica could claim without disturbing any VM:
        the free pool plus the droppable snapshot charge (``register``
        squeezes the pool for a booting replica)."""
        b = self.brokers[host_id]
        return b.free_units + b.snapshot_units()

    def place(self, replica_id: str, units: int, *,
              policy: str = "spread") -> str:
        """Pick the host for a new ``units``-block replica and record the
        placement.  The caller then boots the engine against that host's
        broker (which registers it)."""
        assert policy in PLACEMENTS, policy
        assert replica_id not in self.placements, replica_id
        fits = [h for h in sorted(self.brokers)
                if self.capacity(h) >= units]
        assert fits, \
            f"no host can fit {units} units for {replica_id}: " \
            f"capacities {({h: self.capacity(h) for h in self.brokers})}"
        if policy == "spread":
            host = min(fits, key=lambda h: (-self.capacity(h), h))
        else:                                    # pack: best fit
            host = min(fits, key=lambda h: (self.capacity(h), h))
        self.placements[replica_id] = host
        return host

    # -------------------------------------------------- fleet-wide signals
    def open_order_units(self, replica_id: str) -> int:
        """Blocks ``replica_id`` owes its host's open reclaim orders (the
        router's drain-awareness signal, lifted fleet-wide)."""
        b = self.broker_of(replica_id)
        return b.open_order_units(replica_id) if b is not None else 0

    def snapshot_host(self, key: str, *,
                      exclude: Optional[str] = None) -> Optional[str]:
        """First host (by id — deterministic) whose pool holds a
        RESTORABLE snapshot for ``key``; ``exclude`` skips the would-be
        destination when scouting migration sources."""
        for h in sorted(self.brokers):
            if h != exclude and self.brokers[h].snapshot_restorable(key):
                return h
        return None

    # ----------------------------------------------------------- migration
    def ensure_local(self, key: str, dst_host: str
                     ) -> Optional[MigrationRecord]:
        """Make ``key`` restorable on ``dst_host`` if any peer can supply
        it: a no-op when the destination already holds a restorable copy,
        a cross-host migration otherwise.  Returns the migration record,
        or ``None`` when nothing moved."""
        dst = self.brokers[dst_host]
        if dst.snapshot_restorable(key):
            return None
        return self.migrate_snapshot(key, dst_host)

    def migrate_snapshot(self, key: str, dst_host: str
                         ) -> Optional[MigrationRecord]:
        """Move ``key``'s snapshot from whichever peer holds it to
        ``dst_host``: debit the source pool, model the inter-host copy
        (real bytes / bandwidth + link latency), credit the destination
        pool.  Per-host conservation holds on both ledgers; the copy wall
        is owed by the migrated entry until its first restore claims it."""
        src_host = self.snapshot_host(key, exclude=dst_host)
        if src_host is None:
            self.migration_denied += 1
            return None
        src, dst = self.brokers[src_host], self.brokers[dst_host]
        snap = src.snapshots.peek(key)
        # the entry keeps its owner tenant across hosts: the destination
        # charges its ledger on the SAME tenant's sub-budget account
        if not dst.snapshot_room(key, snap.units, tenant=snap.tenant):
            self.migration_denied += 1           # destination under
            return None                          # pressure: cold-start
        units, nbytes = snap.units, snap.nbytes
        payload, tokens = snap.payload, snap.tokens
        fragments = snap.fragments
        # any transfer wall the source itself still owed compounds: a
        # twice-migrated snapshot pays both hops at its first restore.
        # Sharded entries move one fragment per device — each fragment is
        # its own transfer, so the fixed link latency is paid per
        # fragment while the byte wall stays the total payload over the
        # shared pipe (unsharded entries are the 1-fragment case).
        n_frag = len(fragments) if fragments is not None else 1
        copy_s = snap.copy_seconds + n_frag * self.link_latency_s \
            + nbytes / self.bandwidth_bytes_per_s
        src.snapshot_drop(key)                   # debit: src ledger credits
        ok = dst.snapshot_put(key, units=units, payload=payload,
                              tokens=tokens, nbytes=nbytes,
                              replica_id=snap.replica_id,
                              origin_host=src_host, copy_seconds=copy_s,
                              tenant=snap.tenant, fragments=fragments)
        assert ok, "room check promised space at the destination"
        rec = MigrationRecord(key=key, src=src_host, dst=dst_host,
                              units=units, nbytes=nbytes,
                              copy_seconds=copy_s, at=self._clock())
        self.migrations.append(rec)
        return rec

    # -------------------------------------------------------------- report
    def report(self) -> dict[str, Any]:
        return {
            "hosts": {h: b.report() for h, b in self.brokers.items()},
            "placements": dict(self.placements),
            "migrations": len(self.migrations),
            "migrated_snapshot_bytes": sum(r.nbytes
                                           for r in self.migrations),
            "migration_copy_seconds": sum(r.copy_seconds
                                          for r in self.migrations),
            "migration_denied": self.migration_denied,
        }

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Per-host conservation, fleet-wide: every host's ledger law
        (and order/grant/pool cross-checks) after any fleet event."""
        for b in self.brokers.values():
            b.check_invariants()
        for rid, host in self.placements.items():
            assert host in self.brokers, (rid, host)
