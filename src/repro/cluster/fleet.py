"""Fleet layer: N hosts, one scheduler — placement + warm-state migration.

One ``HostMemoryBroker`` arbitrates ONE host's budget; the fleet
scheduler is the level above (the ROADMAP's multi-host item): it owns a
broker per host, places replicas onto hosts, and moves warm-restart
state *between* hosts, TrEnv-X-style — a host that never ran a function
can still restore its prefix KV from a peer's snapshot pool instead of
paying a cold prefill.

Placement (``place``) is capacity-driven and deterministic:

  * ``spread`` — put the replica on the host with the most reclaimable
    capacity (free pool + droppable snapshot charge); classic load
    spreading, maximizes per-host slack.
  * ``pack``   — best-fit: the fitting host with the LEAST capacity, so
    big contiguous budgets stay available for later replicas.

Ties break on host id; a replica that fits nowhere is a placement error
(the caller sees it immediately, not as a later register failure).

Cross-host snapshot migration (``ensure_local`` / ``migrate_snapshot``):
when the destination host lacks a restorable snapshot for a function but
a peer holds one, the scheduler debits the peer's pool (its ledger
credits the units back to its free pool), charges a modeled inter-host
copy — REAL payload bytes over a configurable ``bandwidth_bytes_per_s``
plus a fixed ``link_latency_s`` — and credits the destination pool.
Content-addressed entries migrate dedup-aware: only pages the
destination's ``PageStore`` LACKS cross the wire (a manifest whose pages
the destination already holds moves metadata only), so migration bytes
shrink with fleet-wide prefix sharing while the contention model is
unchanged.  The
copy wall rides the migrated ``Snapshot`` (``copy_seconds``) and is paid
by the first restore that uses it (``ServeEngine._start_restore`` tags
that event ``source="remote"``), so a remote restore lands strictly
between a local restore and a cold prefill.  Unit conservation stays
per-host throughout: a migration is ``snapshot_drop`` on the source
ledger and ``snapshot_put`` on the destination ledger — units never
teleport between budgets, and ``check_invariants`` proves every host's
``free + granted + escrow + snapshot == budget`` after every fleet
event.

A migration is refused (returns ``None``, nothing mutated) when no peer
holds a restorable copy or the destination pool has no room — the
destination then simply cold-starts, exactly as before the fleet
existed.

Interconnect model: transfers CONTEND.  The scheduler tracks in-flight
migrations (each occupies its endpoints' NICs until its modeled end
time); a new transfer's byte wall is ``nbytes / (bandwidth / (1 + n))``
where ``n`` counts in-flight transfers sharing either endpoint — so a
retirement stampede out of one host slows itself down instead of
teleporting N snapshots over one pipe at full rate.  The fixed per-
fragment ``link_latency_s`` is propagation, not bandwidth, and does not
contend.  ``migration_budget_bytes`` additionally caps the *drain*
(scale-down) bytes in flight at once: a drain migration over budget is
deferred (retried at the next retirement pump), while foreground
``ensure_local`` restores are never deferred — scale-down traffic can
slow them (shared NIC) but never starve them behind an unbounded queue.

Host lifecycle (the autoscaling substrate): ``boot_host`` adds a host;
``retire_host`` / ``begin_retire`` mark one retiring — it stops
accepting placements (``place`` skips it; the router masks its
replicas) — then ``drain_host`` hands its restorable snapshot-pool
entries to peers via the SAME ``migrate_snapshot`` path (TrEnv-X:
retiring nodes share execution state instead of discarding it), and
``finish_retire`` removes the host only once its ledger shows
``free == budget``.  Per-host conservation is re-proved after every
lifecycle event.

``FleetSim`` (``repro.cluster.sim``) drives N hosts of engines on one
deterministic virtual timebase, calls ``ensure_local`` as arrivals are
routed, and — given an ``AutoscalePolicy`` — boots and retires hosts
from the run loop; ``Router``'s ``drain_weighted`` policy consumes the
fleet view (``host_of`` / ``snapshot_host`` / ``open_order_units``) for
its placement tiers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.cluster.host import HostMemoryBroker

PLACEMENTS = ("spread", "pack")


@dataclasses.dataclass
class MigrationRecord:
    """One cross-host snapshot migration: ``key``'s warm state moved from
    ``src`` to ``dst``, paying a modeled ``copy_seconds`` transfer for
    ``nbytes`` bytes ACTUALLY moved — for a content-addressed entry only
    the pages the destination lacked, which may be far below the entry's
    full payload size (and zero for a fully-shared manifest)."""
    key: str
    src: str
    dst: str
    units: int
    nbytes: int
    copy_seconds: float
    at: float                    # fleet-clock timestamp


@dataclasses.dataclass
class _Transfer:
    """An in-flight interconnect transfer: occupies its endpoints' NICs
    until ``end`` (fleet clock), contending with overlapping transfers."""
    src: str
    dst: str
    end: float
    nbytes: int
    drain: bool                  # scale-down traffic (budget-capped)


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Deterministic threshold autoscaler (the paper's Fig. 8 trigger,
    driven from ``FleetSim.run``): boot a host when the active fleet's
    free-unit slack drops below ``low_water``; after ``quiet_ticks``
    consecutive evaluations with slack at/above ``high_water``, begin
    retiring the emptiest host (most free units).  ``min_hosts`` /
    ``max_hosts`` bound the fleet size.

    ``boot_latency_s`` models real provisioning lag: a booted host joins
    the fleet immediately (its capacity is visible, so the trigger does
    not re-fire every tick while one is already coming up) but becomes
    ROUTABLE only after the latency elapses on the fleet clock — which
    makes ``low_water`` a real tuning knob: the margin must cover the
    demand that arrives while the new host is still booting."""
    low_water: int
    high_water: int
    quiet_ticks: int
    min_hosts: int = 1
    max_hosts: int = 8
    boot_latency_s: float = 0.0

    def __post_init__(self):
        assert 0 <= self.low_water <= self.high_water
        assert self.quiet_ticks > 0
        assert 1 <= self.min_hosts <= self.max_hosts
        assert self.boot_latency_s >= 0.0


class FleetScheduler:
    """Owns one ``HostMemoryBroker`` per host: places replicas, serves
    the fleet-wide snapshot view, and migrates warm state across hosts."""

    def __init__(self, *, bandwidth_bytes_per_s: float = float(1 << 30),
                 link_latency_s: float = 5e-4,
                 migration_budget_bytes: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        assert bandwidth_bytes_per_s > 0 and link_latency_s >= 0
        assert migration_budget_bytes is None or migration_budget_bytes > 0
        self.bandwidth_bytes_per_s = bandwidth_bytes_per_s
        self.link_latency_s = link_latency_s
        self.migration_budget_bytes = migration_budget_bytes
        self._clock = clock if clock is not None else (lambda: 0.0)
        self.brokers: dict[str, HostMemoryBroker] = {}
        self.placements: dict[str, str] = {}     # replica -> host
        self.migrations: list[MigrationRecord] = []
        self.migration_denied = 0    # no source / no room at destination
        self.migration_deferred = 0  # drain over migration budget: retried
        self._inflight: list[_Transfer] = []
        # host lifecycle: retiring hosts accept no placements and drain
        # their pools to peers; retired ids stay known so stale
        # placements remain resolvable (their replicas were decommissioned)
        self.retiring: set[str] = set()
        self.retired: set[str] = set()
        # hosts still provisioning: routable only once the fleet clock
        # passes their ready time (they DO count toward capacity/slack,
        # so the autoscale trigger does not stampede while one boots)
        self._ready_at: dict[str, float] = {}
        self.host_boots = 0
        self.host_retires = 0
        self.drain_discarded = 0     # pool entries dropped, not migrated

    def set_clock(self, clock: Callable[[], float]) -> None:
        """Inject the fleet's deterministic timebase (``FleetSim`` passes
        the sum of every host's virtual clock)."""
        self._clock = clock

    # ------------------------------------------------------------ topology
    def add_host(self, host_id: str, broker: HostMemoryBroker) -> None:
        assert host_id not in self.brokers, host_id
        assert host_id not in self.retired, \
            f"host id {host_id} was retired; ids are never reused"
        self.brokers[host_id] = broker

    def host_of(self, replica_id: str) -> Optional[str]:
        return self.placements.get(replica_id)

    def broker_of(self, replica_id: str) -> Optional[HostMemoryBroker]:
        host = self.placements.get(replica_id)
        return self.brokers.get(host) if host is not None else None

    def active_hosts(self) -> list[str]:
        """Hosts currently accepting placements (not retiring), sorted."""
        return sorted(h for h in self.brokers if h not in self.retiring)

    # ----------------------------------------------------------- placement
    def capacity(self, host_id: str, *, tenant: Optional[str] = None) -> int:
        """Units a new ``tenant`` replica could claim without disturbing
        any VM: the free pool plus the snapshot charge a boot-time
        squeeze could ACTUALLY drop.  The probe walks the pool with the
        tenant-fairness rule (another tenant's entries count only down
        to its sub-budget), so ``place`` never promises capacity that
        ``register`` then fails to deliver — summing the whole pool
        charge here was exactly that bug."""
        b = self.brokers[host_id]
        return b.free_units + b.squeezable_snapshot_units(tenant)

    def place(self, replica_id: str, units: int, *,
              policy: str = "spread", tenant: Optional[str] = None) -> str:
        """Pick the host for a new ``units``-block replica and record the
        placement.  The caller then boots the engine against that host's
        broker (which registers it).  Retiring hosts accept no
        placements; ``tenant`` scopes the capacity probe to what that
        tenant's boot squeeze may drop."""
        assert policy in PLACEMENTS, policy
        assert replica_id not in self.placements, replica_id
        fits = [h for h in self.active_hosts()
                if self.capacity(h, tenant=tenant) >= units]
        assert fits, \
            f"no host can fit {units} units for {replica_id}: " \
            f"capacities " \
            f"{({h: self.capacity(h, tenant=tenant) for h in self.active_hosts()})}"
        if policy == "spread":
            host = min(fits, key=lambda h: (-self.capacity(h, tenant=tenant),
                                            h))
        else:                                    # pack: best fit
            host = min(fits, key=lambda h: (self.capacity(h, tenant=tenant),
                                            h))
        self.placements[replica_id] = host
        return host

    # ------------------------------------------------------ host lifecycle
    def boot_host(self, host_id: str, broker: HostMemoryBroker, *,
                  ready_delay: float = 0.0) -> None:
        """Scale-up: add a freshly provisioned host to the fleet.  With
        ``ready_delay`` the host is booked (capacity visible, placements
        allowed) but not ROUTABLE until the fleet clock advances past
        ``now + ready_delay`` — the router masks its replicas until
        then, modeling real provisioning latency."""
        assert ready_delay >= 0.0, ready_delay
        self.add_host(host_id, broker)
        if ready_delay > 0.0:
            self._ready_at[host_id] = self._clock() + ready_delay
        self.host_boots += 1
        self.check_invariants()

    def host_ready(self, host_id: str) -> bool:
        """Has ``host_id`` finished provisioning (routable)?  Hosts
        booted without a delay are ready immediately."""
        at = self._ready_at.get(host_id)
        if at is None:
            return True
        if self._clock() >= at:
            del self._ready_at[host_id]          # provisioning complete
            return True
        return False

    def begin_retire(self, host_id: str) -> None:
        """Mark ``host_id`` retiring: it stops accepting placements (and
        the router masks its replicas), but keeps serving what it has
        until drained."""
        assert host_id in self.brokers, host_id
        self.retiring.add(host_id)

    def drain_host(self, host_id: str, *, force: bool = False
                   ) -> dict[str, int]:
        """One retirement pump: hand the retiring host's snapshot pool to
        peers via ``migrate_snapshot``.  Restorable entries go to the
        non-retiring peer with the most free units that has room;
        metadata-only entries (restorable nowhere) are dropped.  A
        restorable entry with no peer room — or over the drain budget —
        is left for the next pump, unless ``force`` (the end-of-run
        finalization: no foreground traffic remains to protect, so the
        budget is ignored and roomless entries are dropped rather than
        stranding the retirement)."""
        assert host_id in self.retiring, host_id
        b = self.brokers[host_id]
        stats = {"migrated": 0, "deferred": 0, "discarded": 0}
        if b.snapshots is None:
            return stats
        for key in list(b.snapshots.keys()):     # LRU -> MRU
            snap = b.snapshots.peek(key)
            specs = b.snapshot_page_specs(key)   # None for legacy entries
            dst = None
            if snap.restorable:
                for h in sorted((h for h in self.brokers
                                 if h != host_id and h not in self.retiring),
                                key=lambda h: (-self.brokers[h].free_units,
                                               h)):
                    if self.brokers[h].snapshot_room(key, snap.units,
                                                     tenant=snap.tenant,
                                                     pages=specs):
                        dst = h
                        break
                if dst is None and not force:
                    stats["deferred"] += 1       # room may yet appear
                    continue
            if dst is None:
                b.snapshot_drop(key)
                self.drain_discarded += 1
                stats["discarded"] += 1
                continue
            rec = self.migrate_snapshot(key, dst, src_host=host_id,
                                        drain=not force)
            if rec is None:                      # over the drain budget:
                stats["deferred"] += 1           # retried next pump
            else:
                stats["migrated"] += 1
        self.check_invariants()
        return stats

    def finish_retire(self, host_id: str) -> bool:
        """Complete a retirement — only once the host's ledger shows
        ``free == budget`` (nothing granted, escrowed, or pooled).  The
        id moves to ``retired`` so stale placements of decommissioned
        replicas stay resolvable (to "a host that no longer exists")."""
        assert host_id in self.retiring, host_id
        b = self.brokers[host_id]
        if b.free_units != b.budget_units:
            return False
        b.check_invariants()
        del self.brokers[host_id]
        self._ready_at.pop(host_id, None)
        self.retiring.discard(host_id)
        self.retired.add(host_id)
        self.host_retires += 1
        self.check_invariants()
        return True

    def retire_host(self, host_id: str, *, force: bool = False) -> bool:
        """Scripted retirement: mark retiring, drain the pool, and remove
        the host if its ledger is already clean (no replicas registered).
        Returns True when the host is gone; False leaves it retiring for
        further pumps (``drain_host`` / ``finish_retire``) — e.g. its
        replicas must be decommissioned (``HostMemoryBroker.deregister``)
        first."""
        if host_id not in self.retiring:
            self.begin_retire(host_id)
        self.drain_host(host_id, force=force)
        return self.finish_retire(host_id)

    # -------------------------------------------------- fleet-wide signals
    def open_order_units(self, replica_id: str) -> int:
        """Blocks ``replica_id`` owes its host's open reclaim orders (the
        router's drain-awareness signal, lifted fleet-wide)."""
        b = self.broker_of(replica_id)
        return b.open_order_units(replica_id) if b is not None else 0

    def snapshot_host(self, key: str, *,
                      exclude: Optional[str] = None) -> Optional[str]:
        """First host (by id — deterministic) whose pool holds a
        RESTORABLE snapshot for ``key``; ``exclude`` skips the would-be
        destination when scouting migration sources."""
        for h in sorted(self.brokers):
            if h != exclude and self.brokers[h].snapshot_restorable(key):
                return h
        return None

    # ----------------------------------------------------------- migration
    def ensure_local(self, key: str, dst_host: str
                     ) -> Optional[MigrationRecord]:
        """Make ``key`` restorable on ``dst_host`` if any peer can supply
        it: a no-op when the destination already holds a restorable copy,
        a cross-host migration otherwise.  Returns the migration record,
        or ``None`` when nothing moved."""
        dst = self.brokers[dst_host]
        if dst.snapshot_restorable(key):
            return None
        return self.migrate_snapshot(key, dst_host)

    def _contenders(self, src_host: str, dst_host: str, now: float) -> int:
        """Prune finished transfers, then count in-flight ones sharing
        either endpoint's NIC with a new ``src -> dst`` transfer."""
        self._inflight = [t for t in self._inflight if t.end > now]
        ends = (src_host, dst_host)
        return sum(1 for t in self._inflight
                   if t.src in ends or t.dst in ends)

    def _drain_bytes_inflight(self, now: float) -> int:
        self._inflight = [t for t in self._inflight if t.end > now]
        return sum(t.nbytes for t in self._inflight if t.drain)

    def migrate_snapshot(self, key: str, dst_host: str, *,
                         src_host: Optional[str] = None,
                         drain: bool = False) -> Optional[MigrationRecord]:
        """Move ``key``'s snapshot from whichever peer holds it (or the
        explicit ``src_host``) to ``dst_host``: debit the source pool,
        model the inter-host copy (real bytes over the CONTENDED pipe +
        link latency), credit the destination pool.  Per-host
        conservation holds on both ledgers; the copy wall is owed by the
        migrated entry until its first restore claims it.

        ``drain`` marks scale-down traffic: it is deferred (returns
        ``None``, counted ``migration_deferred``) whenever committing it
        would push the in-flight drain bytes over
        ``migration_budget_bytes`` — foreground restores never are."""
        if src_host is None:
            src_host = self.snapshot_host(key, exclude=dst_host)
        if src_host is None:
            self.migration_denied += 1
            return None
        src, dst = self.brokers[src_host], self.brokers[dst_host]
        assert src_host != dst_host and src.snapshot_restorable(key), \
            (key, src_host, dst_host)
        snap = src.snapshots.peek(key)
        specs = src.snapshot_page_specs(key)     # None for legacy entries
        # the entry keeps its owner tenant across hosts: the destination
        # charges its ledger on the SAME tenant's sub-budget account
        if not dst.snapshot_room(key, snap.units, tenant=snap.tenant,
                                 pages=specs):
            self.migration_denied += 1           # destination under
            return None                          # pressure: cold-start
        units, nbytes = snap.units, snap.nbytes
        payload, tokens = snap.payload, snap.tokens
        fragments = snap.fragments
        # dedup-aware transfer sizing: only pages the destination store
        # LACKS cross the wire — a manifest the destination already
        # fully holds moves metadata only (zero bytes, zero hops).
        # Legacy opaque entries move their whole payload.
        if specs is not None:
            size = {d: b for d, _u, b, _p in specs}
            missing = dst.missing_pages(list(size))
            moved_nbytes = sum(size[d] for d in missing)
            n_xfer = len(missing)
        else:
            moved_nbytes = nbytes
            n_xfer = len(fragments) if fragments is not None else 1
        now = self._clock()                      # read ONCE per migration
        if drain and self.migration_budget_bytes is not None \
                and self._drain_bytes_inflight(now) + moved_nbytes \
                > self.migration_budget_bytes:
            self.migration_deferred += 1
            return None
        # any transfer wall the source itself still owed compounds: a
        # twice-migrated snapshot pays both hops at its first restore.
        # Sharded entries move one fragment per device and paged entries
        # one transfer per MISSING page — each is its own transfer, so
        # the fixed link latency (propagation: it does not contend) is
        # paid per transfer while the byte wall is the moved payload
        # over THIS transfer's share of the pipe: in-flight transfers
        # touching either endpoint split the NIC, so n concurrent
        # migrations out of one retiring host each see
        # bandwidth / (1 + n_others) (unsharded legacy entries are the
        # 1-transfer case; an uncontended transfer is the legacy model
        # bit-for-bit).
        share = self.bandwidth_bytes_per_s \
            / (1 + self._contenders(src_host, dst_host, now))
        hop_s = n_xfer * self.link_latency_s + moved_nbytes / share
        copy_s = snap.copy_seconds + hop_s
        if moved_nbytes > 0:
            self._inflight.append(_Transfer(src=src_host, dst=dst_host,
                                            end=now + hop_s,
                                            nbytes=moved_nbytes,
                                            drain=drain))
        src.snapshot_drop(key)                   # debit: src ledger credits
        ok = dst.snapshot_put(key, units=units, payload=payload,
                              tokens=tokens, nbytes=nbytes,
                              replica_id=snap.replica_id,
                              origin_host=src_host, copy_seconds=copy_s,
                              tenant=snap.tenant, fragments=fragments,
                              pages=specs)
        assert ok, "room check promised space at the destination"
        rec = MigrationRecord(key=key, src=src_host, dst=dst_host,
                              units=units, nbytes=moved_nbytes,
                              copy_seconds=copy_s, at=now)
        self.migrations.append(rec)
        return rec

    # -------------------------------------------------------------- report
    def report(self) -> dict[str, Any]:
        return {
            "hosts": {h: b.report() for h, b in self.brokers.items()},
            "placements": dict(self.placements),
            "migrations": len(self.migrations),
            "migrated_snapshot_bytes": sum(r.nbytes
                                           for r in self.migrations),
            "migration_copy_seconds": sum(r.copy_seconds
                                          for r in self.migrations),
            "migration_denied": self.migration_denied,
            "migration_deferred": self.migration_deferred,
            "retiring": sorted(self.retiring),
            "retired": sorted(self.retired),
            "booting": sorted(self._ready_at),
            "host_boots": self.host_boots,
            "host_retires": self.host_retires,
            "drain_discarded": self.drain_discarded,
        }

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Per-host conservation, fleet-wide: every host's ledger law
        (and order/grant/pool cross-checks) after any fleet event —
        including every lifecycle event (boot / drain pump / removal)."""
        for b in self.brokers.values():
            b.check_invariants()
        assert self.retiring <= set(self.brokers), \
            (self.retiring, sorted(self.brokers))
        assert set(self._ready_at) <= set(self.brokers), \
            (sorted(self._ready_at), sorted(self.brokers))
        assert not self.retired & set(self.brokers)
        for rid, host in self.placements.items():
            # a decommissioned replica's placement survives its host
            # (resolvable to "retired"), so stale ids never dangle
            assert host in self.brokers or host in self.retired, (rid, host)
