"""Device topology: the mesh a host exposes to its memory-control plane.

Squeezy segregates hotplugged memory into regions with bounded allocation
lifetimes; on real jax_pallas serving hardware the natural region boundary
is the *device* — a replica's KV spreads across a mesh of accelerators,
each with its own HBM limit, and the host's broker must arbitrate
**per-device** budgets, not one flat pool.  ``DeviceTopology`` is the
pure-metadata description of that mesh: how many devices a host exposes
and how many memory units (blocks) each one holds.

The whole cluster layer treats ``devices=1`` as the exact legacy
configuration: a single-device topology's ledger/broker arithmetic is
bit-identical to the pre-topology scalar-budget code (the regression
tests pin this), which is what makes the per-device refactor a
specialization rather than a fork.

Production topologies come from a real JAX mesh via
``repro.sharding.mesh_topology`` (device count = mesh size) or
``repro.launch.mesh.make_host_topology`` (local devices); tests and the
scenario bank construct them directly with ``DeviceTopology.uniform``.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """Per-device unit budgets for one host's mesh.

    ``budgets[d]`` is device ``d``'s HBM budget in broker units (blocks).
    Replicas span the full mesh (one shard per device), so balanced unit
    flows move ``k // n_devices`` units on every device — the ledger
    asserts divisibility at the flow, keeping per-device conservation
    exact rather than approximate.
    """

    budgets: tuple[int, ...]

    def __post_init__(self):
        assert self.budgets, "a topology needs at least one device"
        assert all(isinstance(b, int) and b > 0 for b in self.budgets), \
            f"per-device budgets must be positive ints: {self.budgets}"

    @property
    def n_devices(self) -> int:
        return len(self.budgets)

    @property
    def total_units(self) -> int:
        return sum(self.budgets)

    @property
    def uniform_budget(self) -> bool:
        return len(set(self.budgets)) == 1

    @classmethod
    def single(cls, budget_units: int) -> "DeviceTopology":
        """The legacy one-flat-pool host: one device owning everything."""
        return cls(budgets=(budget_units,))

    @classmethod
    def uniform(cls, total_units: int, devices: int) -> "DeviceTopology":
        """Split ``total_units`` evenly over ``devices`` (must divide —
        an uneven split would make balanced flows impossible)."""
        assert devices >= 1 and total_units > 0
        assert total_units % devices == 0, \
            f"budget {total_units} does not stripe over {devices} devices"
        return cls(budgets=(total_units // devices,) * devices)

    def assert_balanced(self, units: int, what: str = "flow") -> int:
        """Balanced-flow guard: ``units`` must stripe evenly over the
        mesh.  Returns the per-device share."""
        assert units % self.n_devices == 0, \
            f"{what} of {units} units does not stripe over " \
            f"{self.n_devices} devices"
        return units // self.n_devices

    def report(self) -> dict[str, Any]:
        return {"devices": self.n_devices,
                "budgets": list(self.budgets),
                "total_units": self.total_units}
