"""Multi-replica host layer: broker (hypervisor role), router (FaaS
front-end role), the host-memory snapshot pool (warm-restart state), and
the deterministic co-simulation that couples N ``ServeEngine`` replicas
over one host memory budget."""
from repro.cluster.host import (AlwaysGrantBroker, Grant, HostMemoryBroker,
                                MemoryBroker, ReclaimOrder, StealRecord)
from repro.cluster.router import Router
from repro.cluster.sim import ClusterSim
from repro.cluster.snapshots import Snapshot, SnapshotPool, SqueezeRecord

__all__ = ["AlwaysGrantBroker", "Grant", "HostMemoryBroker", "MemoryBroker",
           "ReclaimOrder", "StealRecord", "Router", "ClusterSim",
           "Snapshot", "SnapshotPool", "SqueezeRecord"]
