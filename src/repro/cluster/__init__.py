"""Multi-replica host layer: broker (hypervisor role), router (FaaS
front-end role), and the deterministic co-simulation that couples N
``ServeEngine`` replicas over one host memory budget."""
from repro.cluster.host import (AlwaysGrantBroker, HostMemoryBroker,
                                MemoryBroker, StealRecord)
from repro.cluster.router import Router
from repro.cluster.sim import ClusterSim

__all__ = ["AlwaysGrantBroker", "HostMemoryBroker", "MemoryBroker",
           "StealRecord", "Router", "ClusterSim"]
