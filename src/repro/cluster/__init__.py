"""Multi-replica host + fleet layer.

Per host: broker (hypervisor role, unit flows owned by a per-host
``BudgetLedger`` — ONE code path checks ``free + granted + escrow +
snapshot == budget``), the host-memory snapshot pool (warm-restart
state), and the router (FaaS front-end role).  Across hosts: the
``FleetScheduler`` places replicas (pack/spread) and migrates snapshots
between host pools (modeled inter-host copy — real bytes, configurable
bandwidth), so a restore on a host that never ran the function lands
between a local restore and a cold prefill.  ``FleetSim`` couples N
hosts of ``ServeEngine`` replicas on one deterministic virtual timebase;
``ClusterSim`` is its single-host specialization.  Router start-path
tiers (``drain_weighted``): local warm > local snapshot > remote
snapshot > least-loaded, drain-penalized by how many blocks a replica
owes to open reclaim orders.  ``repro.cluster.scenarios`` packages the
whole stack into a bank of named, seeded, deterministic multi-tenant
scenarios, each emitting one schema-stable report row (the regression
surface ``benchmarks/run.py --scenarios`` tracks)."""
from repro.cluster.fleet import (AutoscalePolicy, FleetScheduler,
                                 MigrationRecord)
from repro.cluster.host import (AlwaysGrantBroker, Grant, HostMemoryBroker,
                                MemoryBroker, ReclaimOrder, StealRecord)
from repro.cluster.ledger import DEFAULT_TENANT, BudgetLedger
from repro.cluster.router import Router
from repro.cluster.scenarios import (ROW_SCHEMA, SCENARIOS, SMOKE,
                                     TIME_FIELDS, HedgedRoutePolicy,
                                     ModelReplica, run_bank, run_scenario)
from repro.cluster.sim import ClusterSim, FleetSim
from repro.cluster.snapshots import Snapshot, SnapshotPool, SqueezeRecord
from repro.cluster.topology import DeviceTopology

__all__ = ["AlwaysGrantBroker", "AutoscalePolicy", "BudgetLedger",
           "ClusterSim",
           "DEFAULT_TENANT", "DeviceTopology", "FleetSim",
           "FleetScheduler", "Grant", "HedgedRoutePolicy",
           "HostMemoryBroker", "MemoryBroker", "MigrationRecord",
           "ModelReplica", "ROW_SCHEMA", "ReclaimOrder", "Router",
           "SCENARIOS", "SMOKE", "Snapshot", "SnapshotPool",
           "SqueezeRecord", "StealRecord", "TIME_FIELDS", "run_bank",
           "run_scenario"]
