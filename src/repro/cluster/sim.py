"""Fleet co-simulation: N ``ServeEngine`` replicas across M hosts.

Each engine keeps its own virtual clock (advanced by measured wall time of
its device ops).  The sim interleaves them deterministically: always tick
the busy replica whose clock is furthest behind, and route each arrival
only once every busy replica has caught up to its submit time — so routing
decisions see the cluster state "at" the arrival instant, and a fixed
(trace, seed) pair replays identically.

``FleetSim`` is the general form: replicas are grouped into hosts, each
host owns a ``HostMemoryBroker`` (its budget ledger couples only the
replicas placed on it), and a ``FleetScheduler`` moves warm snapshot
state BETWEEN hosts as arrivals are routed (``_localize_snapshot``: when
the chosen replica's host lacks a restorable snapshot that a peer holds,
the scheduler migrates it — debiting the peer's pool, charging the
modeled inter-host copy, crediting the local pool — so the admission
restores remotely-captured state instead of cold-prefilling).

Timebase: each host's broker is stamped with that host's virtual clock
(the sum of its replicas' ``now`` — monotonic, advanced only by ticks),
and the scheduler's fleet clock is the sum over every host.  With one
host this is exactly the old single-host timebase, so ``ClusterSim`` —
now the single-host specialization — replays its traces bit-identically.

The broker couples a host's replicas.  Synchronous mode: a loaded
replica's plug request shrinks an idle one inline
(``_reclaim_from_idlest`` -> the victim's ``reclaim_for_broker``),
charging BOTH clocks with the reclaim stall (the victim does the work,
the requester serializes behind it).  Async mode: the request returns a
``Grant`` immediately and the sim's tick interleaving is what pipelines
the reclaim — order issuance (at the requester's plug), partial
fulfillment (the victim drains a chunk per tick, between its decodes),
and grant completion (the requester claims escrowed fills at its own
tick) all advance on the same deterministic virtual timebase, so the
requester's decode overlaps the victim's drain.
"""
from __future__ import annotations

import warnings
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

from repro.cluster.fleet import AutoscalePolicy
from repro.cluster.router import Router
from repro.serving.request import State, slo_tier_of, tenant_of


class FleetSim:
    """N hosts of engines on one deterministic virtual timebase.

    ``hosts`` maps host id -> {replica id -> engine}; replica ids are
    fleet-unique.  ``brokers`` (host id -> broker) defaults to the
    scheduler's when one is given; each broker's clock is re-stamped with
    its host's virtual time so steal/order/squeeze records replay
    deterministically.  ``scheduler`` (a ``FleetScheduler``) enables
    cross-host snapshot migration at route time."""

    def __init__(self, hosts: dict[str, dict[str, Any]],
                 router: Optional[Router] = None,
                 brokers: Optional[dict[str, Any]] = None,
                 scheduler=None):
        assert hosts and all(hosts.values())
        self.hosts = {h: dict(es) for h, es in hosts.items()}
        self.engines: dict[str, Any] = {}
        self._host_of: dict[str, str] = {}
        for h, es in self.hosts.items():
            for rid, e in es.items():
                assert rid not in self.engines, \
                    f"replica id {rid} appears on two hosts"
                self.engines[rid] = e
                self._host_of[rid] = h
        self.scheduler = scheduler
        if brokers is None:
            # a scheduler may own hosts this sim does not drive
            brokers = {h: b for h, b in scheduler.brokers.items()
                       if h in self.hosts} if scheduler is not None else {}
        else:
            assert all(h in self.hosts for h in brokers), \
                f"brokers keyed off-host: " \
                f"{sorted(set(brokers) - set(hosts))}"
        self._brokers = {h: b for h, b in brokers.items() if b is not None}
        # single-host back-compat: THE broker (metrics expose its report)
        self.broker = next(iter(self._brokers.values())) \
            if len(self._brokers) == 1 else None
        for h, b in self._brokers.items():
            if hasattr(b, "set_clock"):
                b.set_clock(lambda h=h: self.host_now(h))
        if scheduler is not None:
            scheduler.set_clock(self.virtual_now)
            for rid, h in self._host_of.items():
                scheduler.placements.setdefault(rid, h)
        self.router = router or Router()
        if self.router.broker is None and self.broker is not None:
            self.router.broker = self.broker
        if self.router.fleet is None and scheduler is not None:
            self.router.fleet = scheduler
        # autoscaling (set_autoscaler): boot/retire hosts from the run loop
        self._autoscale: Optional[AutoscalePolicy] = None
        self._host_factory: Optional[Callable[[str], tuple]] = None
        self._boot_seq = 0
        self._quiet_evals = 0
        self._decommissioned: set[str] = set()
        self._todos: dict[str, deque] = {}
        self._max_virtual_s = float("inf")
        self._truncated = False

    def set_autoscaler(self, policy: AutoscalePolicy,
                       host_factory: Callable[[str], tuple]) -> None:
        """Arm the threshold autoscaler: evaluated once per run-loop
        iteration.  ``host_factory(host_id) -> (broker, {rid: engine})``
        provisions a new host — its engines must already be registered
        with the returned broker (the ``_build`` pattern); the sim wires
        clocks, placements, and routing.  Requires a scheduler."""
        assert self.scheduler is not None, "autoscaling needs a scheduler"
        self._autoscale = policy
        self._host_factory = host_factory

    # ------------------------------------------------------------- clocks
    def host_now(self, host_id: str) -> float:
        """One host's deterministic timebase: total virtual busy time of
        its replicas.  Each tick advances exactly one replica's clock, so
        deltas of this sum measure the victim-side work between any two
        of that host's broker events."""
        return sum(e.now for e in self.hosts[host_id].values())

    def virtual_now(self) -> float:
        """The fleet clock: total virtual busy time across every host
        (stamps ``MigrationRecord``s and single-host broker events)."""
        return sum(e.now for e in self.engines.values())

    # ------------------------------------------------------------------ run
    def run(self, requests: list, max_virtual_s: float = 1e9,
            max_ticks: int = 500_000) -> dict[str, Any]:
        arrivals = deque(sorted(requests, key=lambda r: r.submit_s))
        self._todos = {rid: deque() for rid in self.engines}
        self._max_virtual_s = max_virtual_s
        todos = self._todos
        ticks = 0
        busy = self._busy

        while ticks < max_ticks:
            if self._autoscale is not None:
                self._autoscale_step()
            busy_ids = [rid for rid in self.engines if busy(rid)]
            if arrivals:
                t_arr = arrivals[0].submit_s
                lagging = [r for r in busy_ids
                           if self.engines[r].now < t_arr]
                if lagging:
                    rid = min(lagging,
                              key=lambda r: (self.engines[r].now, r))
                    self.engines[rid]._tick(todos[rid])
                    ticks += 1
                    continue
                req = arrivals.popleft()
                backlog = {r: len(todos[r]) for r in self.engines}
                target = self.router.route(req, self.engines, backlog)
                self._localize_snapshot(req, target)
                todos[target].append(req)
                continue
            if not busy_ids:
                if self._autoscale is not None:
                    self._finalize_retirements()
                break
            rid = min(busy_ids, key=lambda r: (self.engines[r].now, r))
            self.engines[rid]._tick(todos[rid])
            ticks += 1
        # a run that exhausted ``max_ticks`` with work still queued is NOT
        # a completed run — flag it loudly instead of returning metrics
        # indistinguishable from a finished trace
        self._truncated = bool(arrivals
                               or any(busy(r) for r in self.engines))
        if self._truncated:
            warnings.warn(
                f"FleetSim.run truncated at max_ticks={max_ticks}: "
                f"{len(arrivals)} arrivals unrouted, "
                f"{sum(busy(r) for r in self.engines)} replicas still "
                f"busy — metrics are partial", RuntimeWarning,
                stacklevel=2)
        return self.metrics()

    def _busy(self, rid: str) -> bool:
        e = self.engines[rid]
        host_work = getattr(e, "host_work", None)
        return bool(self._todos[rid] or e.pending or e.active
                    or any(e.warm.values())
                    or (host_work is not None and host_work())) \
            and e.now < self._max_virtual_s

    # ------------------------------------------------------- autoscaling
    def _autoscale_step(self) -> None:
        """One autoscaler evaluation (every run-loop iteration): pump
        in-progress retirements, then apply the threshold policy to the
        active fleet's free-unit slack — boot below the low-water mark,
        begin retiring the emptiest host after a sustained quiet streak
        at/above the high-water mark.  Purely a function of fleet state,
        so a fixed (trace, seed) pair autoscales identically."""
        sched, pol = self.scheduler, self._autoscale
        self._pump_retiring(force=False)
        active = [h for h in sched.brokers if h not in sched.retiring]
        slack = sum(sched.brokers[h].free_units for h in active)
        if slack < pol.low_water and len(active) < pol.max_hosts:
            self._boot_host()
            self._quiet_evals = 0
            return
        if slack >= pol.high_water:
            self._quiet_evals += 1
        else:
            self._quiet_evals = 0
        if self._quiet_evals >= pol.quiet_ticks \
                and len(active) > pol.min_hosts:
            # retire the emptiest DRIVEN host (most free units, tie -> id)
            cands = [h for h in active if h in self.hosts]
            if cands:
                victim = min(cands,
                             key=lambda h: (-sched.brokers[h].free_units, h))
                sched.begin_retire(victim)
            self._quiet_evals = 0

    def _boot_host(self) -> None:
        """Scale-up: provision a host via the factory and wire it into
        the running sim (clock, todos, placements, routing)."""
        sched = self.scheduler
        hid = f"as{self._boot_seq}"
        while hid in self.hosts or hid in sched.brokers \
                or hid in sched.retired:
            self._boot_seq += 1
            hid = f"as{self._boot_seq}"
        broker, engines = self._host_factory(hid)
        assert engines, f"host factory produced no replicas for {hid}"
        sched.boot_host(hid, broker,
                        ready_delay=self._autoscale.boot_latency_s)
        self.hosts[hid] = dict(engines)
        self._brokers[hid] = broker
        if hasattr(broker, "set_clock"):
            broker.set_clock(lambda h=hid: self.host_now(h))
        for rid, e in engines.items():
            assert rid not in self.engines, \
                f"replica id {rid} already exists in the fleet"
            self.engines[rid] = e
            self._host_of[rid] = hid
            self._todos[rid] = deque()
            sched.placements[rid] = hid
        sched.check_invariants()

    def _pump_retiring(self, *, force: bool) -> None:
        """Advance retirements of driven hosts: once a retiring host's
        replicas are all idle, decommission them (``deregister`` settles
        grants/orders and returns their units), drain the snapshot pool
        to peers, and remove the host when its ledger is clean.  The
        host's engines stay in ``self.engines`` forever — the fleet
        clock is the sum of engine clocks, so removing one would jump
        time backwards; the router masks them via the scheduler."""
        sched = self.scheduler
        for h in sorted(sched.retiring & set(self.hosts)
                        - self._decommissioned):
            if any(self._busy(r) for r in self.hosts[h]):
                continue
            b = sched.brokers[h]
            for rid in sorted(self.hosts[h]):
                if rid in b.granted:
                    b.deregister(rid)
            sched.drain_host(h, force=force)
            if sched.finish_retire(h):
                self._decommissioned.add(h)

    def _finalize_retirements(self) -> None:
        """End-of-trace pass: no arrivals and nothing busy, so complete
        every in-progress retirement deterministically — the drain
        budget protects foreground traffic that no longer exists, so the
        force pump ignores it (and drops entries with no peer room
        rather than stranding the retirement forever)."""
        self._pump_retiring(force=True)

    def _localize_snapshot(self, req, target: str) -> None:
        """Fleet migration hook, at route time: if the chosen replica's
        host lacks a restorable snapshot for the function but a peer
        holds one, migrate it now so the admission restores instead of
        cold-prefilling.  Skipped when the replica holds a warm row (an
        adopt beats any restore — the copy would be wasted), for
        batch-tier traffic (it starts cold by design — paying an
        inter-host copy for it would spend exactly the capacity the tier
        split protects), and on single-host sims (nowhere to migrate
        from)."""
        if self.scheduler is None or len(self._brokers) < 2:
            return
        if slo_tier_of(req) == "batch":
            return
        if self.engines[target].warm.get(req.profile.name):
            return
        host = self._host_of[target]
        # a retiring host is draining its pool — don't migrate INTO it
        # (the router only lands here when the whole fleet is retiring)
        if host in getattr(self.scheduler, "retiring", ()):
            return
        self.scheduler.ensure_local(req.profile.name, host)

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict[str, Any]:
        per = {rid: e.metrics() for rid, e in self.engines.items()}
        done = [r for e in self.engines.values() for r in e.done]
        lat = [r.latency for r in done
               if r.latency is not None and r.state is State.DONE]
        engines = self.engines.values()
        out: dict[str, Any] = {
            "completed": sum(r.state is State.DONE for r in done),
            "killed": sum(r.state is State.KILLED for r in done),
            "truncated": self._truncated,
            "latency_p50": float(np.percentile(lat, 50)) if lat else None,
            # a 1-sample "percentile" is just that sample — meaningless as
            # a tail statistic, so report None until there are >= 2
            "latency_p99": float(np.percentile(lat, 99))
            if len(lat) >= 2 else None,
            "reclaimed_bytes": sum(m["reclaimed_bytes"]
                                   for m in per.values()),
            "migrated_bytes": sum(m["migrated_bytes"] for m in per.values()),
            "reclaim_events": sum(m["reclaim_events"] for m in per.values()),
            "per_replica": per,
            "routed": dict(self.router.routed),
            # authoritative start-path counters (engine-side: the path that
            # actually ran) vs the router's route-time predictions
            "warm_hits": sum(getattr(e, "warm_starts", 0) for e in engines),
            "restore_starts": sum(getattr(e, "restore_starts", 0)
                                  for e in engines),
            "remote_restore_starts": sum(
                getattr(e, "remote_restore_starts", 0) for e in engines),
            "cold_starts": sum(getattr(e, "cold_starts", 0)
                               for e in engines),
            "warm_routes": self.router.warm_routes,
            "snapshot_routes": self.router.snapshot_routes,
            "remote_routes": self.router.remote_routes,
            "snapshot_migrations": len(self.scheduler.migrations)
            if self.scheduler is not None else 0,
            # per-device occupancy surface (observability only: routing
            # keys never read this, so devices=1 traces stay identical)
            "device_occupancy": {h: b.ledger.device_report()
                                 for h, b in self._brokers.items()
                                 if hasattr(b, "ledger")},
        }
        by_tenant: dict[str, dict[str, int]] = {}
        for r in done:
            t = tenant_of(r) or "default"
            d = by_tenant.setdefault(t, {"completed": 0, "killed": 0})
            if r.state is State.DONE:
                d["completed"] += 1
            elif r.state is State.KILLED:
                d["killed"] += 1
        out["by_tenant"] = by_tenant
        if self.broker is not None:
            out["broker"] = self.broker.report()
        if self.scheduler is not None:
            out["fleet"] = self.scheduler.report()
        return out


class ClusterSim(FleetSim):
    """Single-host specialization (the pre-fleet interface): N replicas,
    one broker, no cross-host migration.  ``FleetSim`` with one host
    replays these traces bit-identically — the regression tests pin that
    seam."""

    def __init__(self, engines: dict[str, Any], router: Optional[Router]
                 = None, broker=None):
        assert engines
        super().__init__({"host0": dict(engines)}, router,
                         brokers={"host0": broker})
