"""Multi-replica co-simulation: N ``ServeEngine`` replicas, one host.

Each engine keeps its own virtual clock (advanced by measured wall time of
its device ops).  The sim interleaves them deterministically: always tick
the busy replica whose clock is furthest behind, and route each arrival
only once every busy replica has caught up to its submit time — so routing
decisions see the cluster state "at" the arrival instant, and a fixed
(trace, seed) pair replays identically.

The broker couples the replicas.  Synchronous mode: a loaded replica's
plug request shrinks an idle one inline (``_reclaim_from_idlest`` -> the
victim's ``reclaim_for_broker``), charging BOTH clocks with the reclaim
stall (the victim does the work, the requester serializes behind it).
Async mode: the request returns a ``Grant`` immediately and the sim's
tick interleaving is what pipelines the reclaim — order issuance (at the
requester's plug), partial fulfillment (the victim drains a chunk per
tick, between its decodes), and grant completion (the requester claims
escrowed fills at its own tick) all advance on the same deterministic
virtual timebase, so the requester's decode overlaps the victim's drain.

The sim hands the broker its virtual clock (total virtual busy time across
replicas — monotonic, advanced only by ticks) so steal records and order
timestamps are deterministic for a fixed (trace, seed), not wall-clock
noise.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Optional

import numpy as np

from repro.cluster.router import Router
from repro.serving.request import State


class ClusterSim:
    def __init__(self, engines: dict[str, Any], router: Optional[Router]
                 = None, broker=None):
        assert engines
        self.engines = dict(engines)
        self.router = router or Router()
        self.broker = broker          # kept for metrics; engines hold a ref
        if broker is not None and hasattr(broker, "set_clock"):
            broker.set_clock(self.virtual_now)
        if self.router.broker is None:
            self.router.broker = broker

    def virtual_now(self) -> float:
        """Deterministic host timebase: total virtual busy time.  Each
        tick advances exactly one replica's clock, so deltas of this sum
        measure the victim-side work between any two broker events."""
        return sum(e.now for e in self.engines.values())

    # ------------------------------------------------------------------ run
    def run(self, requests: list, max_virtual_s: float = 1e9,
            max_ticks: int = 500_000) -> dict[str, Any]:
        arrivals = deque(sorted(requests, key=lambda r: r.submit_s))
        todos = {rid: deque() for rid in self.engines}
        ticks = 0

        def busy(rid: str) -> bool:
            e = self.engines[rid]
            host_work = getattr(e, "host_work", None)
            return bool(todos[rid] or e.pending or e.active
                        or any(e.warm.values())
                        or (host_work is not None and host_work())) \
                and e.now < max_virtual_s

        while ticks < max_ticks:
            busy_ids = [rid for rid in self.engines if busy(rid)]
            if arrivals:
                t_arr = arrivals[0].submit_s
                lagging = [r for r in busy_ids
                           if self.engines[r].now < t_arr]
                if lagging:
                    rid = min(lagging,
                              key=lambda r: (self.engines[r].now, r))
                    self.engines[rid]._tick(todos[rid])
                    ticks += 1
                    continue
                req = arrivals.popleft()
                backlog = {r: len(todos[r]) for r in self.engines}
                target = self.router.route(req, self.engines, backlog)
                todos[target].append(req)
                continue
            if not busy_ids:
                break
            rid = min(busy_ids, key=lambda r: (self.engines[r].now, r))
            self.engines[rid]._tick(todos[rid])
            ticks += 1
        return self.metrics()

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict[str, Any]:
        per = {rid: e.metrics() for rid, e in self.engines.items()}
        done = [r for e in self.engines.values() for r in e.done]
        lat = [r.latency for r in done
               if r.latency is not None and r.state is State.DONE]
        engines = self.engines.values()
        out: dict[str, Any] = {
            "completed": sum(r.state is State.DONE for r in done),
            "killed": sum(r.state is State.KILLED for r in done),
            "latency_p50": float(np.percentile(lat, 50)) if lat else None,
            "latency_p99": float(np.percentile(lat, 99)) if lat else None,
            "reclaimed_bytes": sum(m["reclaimed_bytes"]
                                   for m in per.values()),
            "migrated_bytes": sum(m["migrated_bytes"] for m in per.values()),
            "reclaim_events": sum(m["reclaim_events"] for m in per.values()),
            "per_replica": per,
            "routed": dict(self.router.routed),
            # authoritative start-path counters (engine-side: the path that
            # actually ran) vs the router's route-time predictions
            "warm_hits": sum(getattr(e, "warm_starts", 0) for e in engines),
            "restore_starts": sum(getattr(e, "restore_starts", 0)
                                  for e in engines),
            "cold_starts": sum(getattr(e, "cold_starts", 0)
                               for e in engines),
            "warm_routes": self.router.warm_routes,
            "snapshot_routes": self.router.snapshot_routes,
        }
        if self.broker is not None:
            out["broker"] = self.broker.report()
        return out
