"""Per-host budget ledger: the conservation law with a single owner.

Every host-level memory flow — boot-time plugs, grant fills, unplug
releases, escrowed reclaim-order proceeds, snapshot-pool charges — moves
units between exactly four ledger accounts:

    free        the host pool (unowned, grantable now)
    granted     per-replica holdings (the VMs' plugged memory)
    escrow      reclaim-order proceeds drained by victims but not yet
                claimed by their requesters (in flight between VMs)
    snapshot    the host snapshot pool's charge (persisted warm-restart
                state, droppable under pressure)

and the invariant the whole test suite anchors on is checked in ONE
place, ``check``::

    free + sum(granted) + escrow + snapshot == budget

Devices: the ledger keeps each account as a **per-device vector** over
the host's ``DeviceTopology`` (``repro.cluster.topology``) — one account
column per device of the mesh the host exposes — and the conservation
law holds per device::

    free_d + sum(granted_d) + escrow_d + snapshot_d == budget_d

for every device ``d``, checked in the same single ``check`` code path
as the host-wide and per-tenant laws (which are its sums).  Flows are
either *balanced* (``dev=None``: units stripe evenly over the mesh —
asserted divisible, so per-device conservation is exact) or
*single-device* (``dev=d``: an escrow fill from one shard of a reclaim
order).  A ``devices=1`` topology makes every flow trivially balanced
and the arithmetic bit-identical to the pre-topology scalar ledger —
the regression tests pin that equivalence.

``HostMemoryBroker`` used to own these counters inline; extracting them
lets the fleet layer (``repro.cluster.fleet``) run N hosts with N
independent ledgers and assert per-host conservation after every fleet
event — including cross-host snapshot migrations, which are a
``snapshot_credit`` on the source ledger and a ``snapshot_charge`` on
the destination one, never a unit teleporting between budgets.

Tenants: the ledger optionally splits the budget into per-tenant
sub-budgets (``tenants={name: units}``, summing exactly to the budget).
Every replica belongs to a tenant (``carve(..., tenant=)``), escrow
fills are attributed to the *requesting* grant's tenant, and snapshot
charges carry their owner tenant — so the host accounts are exactly the
tenant account sums and the conservation law extends to

    sum_over_tenants(free_t + granted_t + escrow_t + snapshot_t) == budget

where ``free_t = sub_budget_t - usage_t`` may go *negative* for a tenant
overdrawn into host slack (grants are work-conserving).  The fairness
rule built on these accounts lives broker-side: one tenant's grant can
squeeze another tenant's snapshots only while the owner stays at or
above its sub-budget afterwards (``HostMemoryBroker._squeeze_snapshots``).
Without an explicit ``tenants=`` map the ledger runs one implicit
``"default"`` tenant owning the whole budget, and every pre-tenant call
site behaves identically.  Tenant accounts stay host-scalar: replicas
span the full mesh, so a tenant's per-device footprint is its host
footprint striped over the devices.

Each verb asserts its own preconditions (no negative balances, no
overdrafts, balanced flows actually balanced), so an illegal flow fails
loudly at the flow, not later at a ``check`` that can no longer say who
leaked.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.cluster.topology import DeviceTopology

DEFAULT_TENANT = "default"


class BudgetLedger:
    """Unit-conservation ledger for one host's memory budget, kept as
    per-device account vectors over the host's ``DeviceTopology``."""

    def __init__(self, budget_units: Optional[int] = None,
                 tenants: Optional[dict[str, int]] = None,
                 topology: Optional[DeviceTopology] = None):
        if topology is None:
            assert budget_units is not None and budget_units > 0
            topology = DeviceTopology.single(budget_units)
        assert budget_units is None \
            or budget_units == topology.total_units, \
            f"budget {budget_units} != topology total {topology.total_units}"
        self.topology = topology
        self.budget_units = topology.total_units
        if tenants is None:
            tenants = {DEFAULT_TENANT: self.budget_units}
        assert tenants and all(v >= 0 for v in tenants.values()), tenants
        assert sum(tenants.values()) == self.budget_units, \
            f"tenant sub-budgets {tenants} must sum to budget " \
            f"{self.budget_units}"
        self.sub_budgets: dict[str, int] = dict(tenants)
        # per-device account vectors: THE state.  The scalar accounts the
        # broker (and every pre-topology call site) reads are their sums.
        self._free_dev: list[int] = list(topology.budgets)
        self._granted_dev: dict[str, list[int]] = {}
        self._escrow_dev: list[int] = [0] * topology.n_devices
        self._snapshot_dev: list[int] = [0] * topology.n_devices
        # scalar view of granted, maintained alongside the vectors (the
        # broker exposes this dict directly; ``check`` cross-verifies it)
        self.granted: dict[str, int] = {}
        # tenant attribution: replicas map to tenants; escrow and snapshot
        # units carry their owning tenant explicitly (granted is derived
        # from the replica map, so it cannot diverge)
        self.tenant_of: dict[str, str] = {}
        self._tenant_escrow: dict[str, int] = {t: 0 for t in tenants}
        self._tenant_snapshot: dict[str, int] = {t: 0 for t in tenants}

    # ------------------------------------------------------- device views
    @property
    def n_devices(self) -> int:
        return self.topology.n_devices

    @property
    def free_units(self) -> int:
        return sum(self._free_dev)

    @property
    def escrow_units(self) -> int:
        return sum(self._escrow_dev)

    @property
    def snapshot_units(self) -> int:
        return sum(self._snapshot_dev)

    def free_dev(self, dev: int) -> int:
        return self._free_dev[dev]

    def granted_dev(self, replica_id: str) -> tuple[int, ...]:
        return tuple(self._granted_dev[replica_id])

    def balanced_free(self) -> int:
        """Units a *balanced* flow can still take from the pool: the
        scarcest device bounds every shard (== ``free_units`` on a
        single-device topology)."""
        return min(self._free_dev) * self.n_devices

    def device_report(self) -> list[dict[str, int]]:
        """Per-device account snapshot (occupancy surface for reports,
        demos, and the scenario rows)."""
        return [{"budget": self.topology.budgets[d],
                 "free": self._free_dev[d],
                 "granted": sum(v[d] for v in self._granted_dev.values()),
                 "escrow": self._escrow_dev[d],
                 "snapshot": self._snapshot_dev[d]}
                for d in range(self.n_devices)]

    def _per(self, units: int, what: str) -> int:
        return self.topology.assert_balanced(units, what)

    # -------------------------------------------------------------- tenants
    def resolve_tenant(self, tenant: Optional[str] = None) -> str:
        """Validate ``tenant``; ``None``/empty falls back to the sole
        tenant (an explicit name is required on multi-tenant ledgers)."""
        if tenant:
            assert tenant in self.sub_budgets, \
                f"unknown tenant {tenant!r} (have {sorted(self.sub_budgets)})"
            return tenant
        assert len(self.sub_budgets) == 1, \
            "multi-tenant ledger: an explicit tenant is required"
        return next(iter(self.sub_budgets))

    def tenant_granted(self, tenant: str) -> int:
        return sum(u for r, u in self.granted.items()
                   if self.tenant_of[r] == tenant)

    def tenant_escrow(self, tenant: str) -> int:
        return self._tenant_escrow[tenant]

    def tenant_snapshot(self, tenant: str) -> int:
        return self._tenant_snapshot[tenant]

    def tenant_usage(self, tenant: str) -> int:
        """Units the tenant currently holds across granted + escrow +
        snapshot (its footprint against its sub-budget)."""
        return self.tenant_granted(tenant) + self._tenant_escrow[tenant] \
            + self._tenant_snapshot[tenant]

    def tenant_free(self, tenant: str) -> int:
        """Sub-budget headroom; negative = overdrawn into host slack."""
        return self.sub_budgets[tenant] - self.tenant_usage(tenant)

    def tenant_report(self) -> dict[str, Any]:
        return {t: {"sub_budget": self.sub_budgets[t],
                    "granted": self.tenant_granted(t),
                    "escrow": self._tenant_escrow[t],
                    "snapshot": self._tenant_snapshot[t],
                    "free": self.tenant_free(t)}
                for t in sorted(self.sub_budgets)}

    # ------------------------------------------------------------- replicas
    def carve(self, replica_id: str, units: int,
              tenant: Optional[str] = None) -> None:
        """Boot-time plug: carve a new replica's initial holding out of
        the free pool, binding the replica to its tenant.  Balanced: a
        replica spans the whole mesh, one shard per device."""
        assert replica_id not in self.granted, replica_id
        per = self._per(units, f"carve for {replica_id}")
        assert 0 <= units and all(per <= f for f in self._free_dev), \
            f"budget exhausted carving {units} for {replica_id}: " \
            f"free {self._free_dev}"
        self.tenant_of[replica_id] = self.resolve_tenant(tenant)
        for d in range(self.n_devices):
            self._free_dev[d] -= per
        self._granted_dev[replica_id] = [per] * self.n_devices
        self.granted[replica_id] = units

    def take_free(self, replica_id: str, want: int) -> int:
        """Grant fill: move up to ``want`` units free -> granted,
        balanced over the mesh (the scarcest device clips every shard).
        Never overdrafts; returns units moved."""
        assert replica_id in self.granted, replica_id
        take = min(max(want, 0), self.balanced_free())
        take -= take % self.n_devices
        per = take // self.n_devices
        for d in range(self.n_devices):
            self._free_dev[d] -= per
            self._granted_dev[replica_id][d] += per
        self.granted[replica_id] += take
        return take

    def release(self, replica_id: str, units: int) -> None:
        """Unplug completion: granted -> free, balanced."""
        assert 0 < units <= self.granted.get(replica_id, 0), \
            f"{replica_id} returning {units} units it was never granted"
        per = self._per(units, f"release by {replica_id}")
        vec = self._granted_dev[replica_id]
        assert all(per <= v for v in vec), \
            f"{replica_id} releasing {units} units its device shards " \
            f"{vec} cannot cover"
        for d in range(self.n_devices):
            vec[d] -= per
            self._free_dev[d] += per
        self.granted[replica_id] -= units

    def forget(self, replica_id: str) -> None:
        """VM teardown (host retirement): drop an emptied replica's
        account.  The replica must have released its whole holding first
        — forgetting a non-zero grant would leak units — so this only
        removes the (all-zero) account rows and the tenant binding."""
        assert replica_id in self.granted, replica_id
        assert self.granted[replica_id] == 0, \
            f"{replica_id} still holds {self.granted[replica_id]} units"
        assert all(v == 0 for v in self._granted_dev[replica_id])
        del self.granted[replica_id]
        del self._granted_dev[replica_id]
        del self.tenant_of[replica_id]

    # --------------------------------------------------------------- escrow
    def escrow_fill(self, victim: str, units: int, *,
                    requester: Optional[str] = None,
                    dev: Optional[int] = None) -> None:
        """Order drain: a victim's surrendered units enter escrow (owned
        by an open grant, awaiting the requester's claim).  The escrow is
        attributed to the *requester's* tenant — the grant owns those
        units now — falling back to the victim's tenant when no requester
        is named (direct ledger drives).  ``dev`` names the single device
        one shard of a reclaim order drained on; ``None`` is a balanced
        fill over the whole mesh."""
        assert 0 < units <= self.granted.get(victim, 0), (victim, units)
        owner = requester if requester in self.tenant_of else victim
        vec = self._granted_dev[victim]
        if dev is None:
            per = self._per(units, f"escrow fill from {victim}")
            assert all(per <= v for v in vec), (victim, units, vec)
            for d in range(self.n_devices):
                vec[d] -= per
                self._escrow_dev[d] += per
        else:
            assert 0 <= dev < self.n_devices, dev
            assert units <= vec[dev], \
                f"{victim} shard {dev} holds {vec[dev]}, draining {units}"
            vec[dev] -= units
            self._escrow_dev[dev] += units
        self.granted[victim] -= units
        self._tenant_escrow[self.tenant_of[owner]] += units

    def escrow_claim(self, replica_id: str, units: int) -> None:
        """Grant completion: escrow -> the requester's holding.  Claims
        are always balanced — only shard-coherent stripes (every device's
        fill present) ever become claimable."""
        assert 0 < units <= self.escrow_units, (units, self.escrow_units)
        assert replica_id in self.granted, replica_id
        t = self.tenant_of[replica_id]
        assert units <= self._tenant_escrow[t], \
            f"tenant {t} claiming {units} escrowed units it owns " \
            f"{self._tenant_escrow[t]} of"
        per = self._per(units, f"escrow claim by {replica_id}")
        assert all(per <= e for e in self._escrow_dev), \
            f"claim of {units} not covered per-device: {self._escrow_dev}"
        for d in range(self.n_devices):
            self._escrow_dev[d] -= per
            self._granted_dev[replica_id][d] += per
        self._tenant_escrow[t] -= units
        self.granted[replica_id] += units

    def escrow_release(self, units: int, *, requester: str,
                       dev: Optional[int] = None) -> None:
        """Escrow -> free: unwind stranded *incoherent* fills (an order
        closed with some shards drained and their siblings canceled, so
        the stripe can never complete).  The requester's grant owned the
        escrow; its tenant's account is debited.  Single-device by
        nature (the stranded shards are the uneven ones)."""
        if units == 0:
            return
        t = self.tenant_of[requester] if requester in self.tenant_of \
            else self.resolve_tenant(None)
        assert 0 < units <= self._tenant_escrow[t], \
            (units, t, self._tenant_escrow)
        if dev is None:
            per = self._per(units, "escrow release")
            assert all(per <= e for e in self._escrow_dev)
            for d in range(self.n_devices):
                self._escrow_dev[d] -= per
                self._free_dev[d] += per
        else:
            assert 0 <= dev < self.n_devices, dev
            assert units <= self._escrow_dev[dev], \
                (units, dev, self._escrow_dev)
            self._escrow_dev[dev] -= units
            self._free_dev[dev] += units
        self._tenant_escrow[t] -= units

    # ------------------------------------------------------------- snapshot
    def snapshot_charge(self, units: int,
                        tenant: Optional[str] = None) -> None:
        """Pool insert: free -> snapshot charge, owned by ``tenant``.
        Balanced: a sharded snapshot carries one fragment per device."""
        assert 0 < units <= self.free_units, (units, self.free_units)
        per = self._per(units, "snapshot charge")
        assert all(per <= f for f in self._free_dev), \
            f"snapshot charge of {units} not covered per-device: " \
            f"{self._free_dev}"
        for d in range(self.n_devices):
            self._free_dev[d] -= per
            self._snapshot_dev[d] += per
        self._tenant_snapshot[self.resolve_tenant(tenant)] += units

    def snapshot_credit(self, units: int,
                        tenant: Optional[str] = None) -> None:
        """Pool drop/evict/squeeze: snapshot charge -> free.  A zero
        credit is a no-op (callers pass through ``pool.drop`` returns)."""
        if units == 0:
            return
        assert 0 < units <= self.snapshot_units, \
            (units, self.snapshot_units)
        t = self.resolve_tenant(tenant)
        assert units <= self._tenant_snapshot[t], \
            f"tenant {t} crediting {units} snapshot units it owns " \
            f"{self._tenant_snapshot[t]} of"
        per = self._per(units, "snapshot credit")
        assert all(per <= s for s in self._snapshot_dev)
        for d in range(self.n_devices):
            self._snapshot_dev[d] -= per
            self._free_dev[d] += per
        self._tenant_snapshot[t] -= units

    def snapshot_reattribute(self, units: int,
                             frm: Optional[str] = None,
                             to: Optional[str] = None) -> None:
        """Shared-page owner handoff: the owning tenant's last reference
        to a still-referenced page dropped, so its charge moves to a
        surviving referencing tenant.  Pure attribution — the device
        vectors and the host snapshot total are untouched — so evicting
        a shared page never strands charge on a tenant that no longer
        references it."""
        assert units >= 0, units
        f, t = self.resolve_tenant(frm), self.resolve_tenant(to)
        if units == 0 or f == t:
            return
        assert units <= self._tenant_snapshot[f], \
            f"tenant {f} reattributing {units} snapshot units it owns " \
            f"{self._tenant_snapshot[f]} of"
        self._tenant_snapshot[f] -= units
        self._tenant_snapshot[t] += units

    # ------------------------------------------------------------ invariant
    def check(self) -> None:
        """THE conservation law — the one code path per host that proves
        no unit was leaked or double-granted: per-device, host-wide, AND
        per-tenant."""
        assert all(f >= 0 for f in self._free_dev), self._free_dev
        assert all(e >= 0 for e in self._escrow_dev), self._escrow_dev
        assert all(s >= 0 for s in self._snapshot_dev), self._snapshot_dev
        assert all(v >= 0 for vec in self._granted_dev.values()
                   for v in vec)
        # per-device conservation: every device's column balances against
        # ITS budget — the host-wide law below is this law's sum
        for d in range(self.n_devices):
            assert self._free_dev[d] \
                + sum(v[d] for v in self._granted_dev.values()) \
                + self._escrow_dev[d] + self._snapshot_dev[d] \
                == self.topology.budgets[d], \
                f"device {d} units leaked or double-granted"
        # the scalar granted view cannot diverge from the device vectors
        assert set(self.granted) == set(self._granted_dev)
        for r, vec in self._granted_dev.items():
            assert self.granted[r] == sum(vec), \
                f"{r}: scalar granted {self.granted[r]} != shards {vec}"
        assert self.free_units + sum(self.granted.values()) \
            + self.escrow_units + self.snapshot_units \
            == self.budget_units, "host units leaked or double-granted"
        # tenant accounts sum exactly to the host accounts
        assert sum(self.sub_budgets.values()) == self.budget_units
        assert set(self.tenant_of.values()) <= set(self.sub_budgets)
        assert all(v >= 0 for v in self._tenant_escrow.values())
        assert all(v >= 0 for v in self._tenant_snapshot.values())
        assert sum(self._tenant_escrow.values()) == self.escrow_units, \
            "tenant escrow attribution diverged from the host account"
        assert sum(self._tenant_snapshot.values()) == self.snapshot_units, \
            "tenant snapshot attribution diverged from the host account"
        # free_t is derived (sub_budget - usage, may be negative for an
        # overdrawn tenant), so this sum is the real cross-check that the
        # per-tenant accounts partition the host budget exactly
        assert sum(self.tenant_free(t) for t in self.sub_budgets) \
            == self.free_units, "tenant free headroom diverged"
        assert sum(self.tenant_free(t) + self.tenant_granted(t)
                   + self._tenant_escrow[t] + self._tenant_snapshot[t]
                   for t in self.sub_budgets) == self.budget_units, \
            "tenant conservation law violated"
