"""Per-host budget ledger: the conservation law with a single owner.

Every host-level memory flow — boot-time plugs, grant fills, unplug
releases, escrowed reclaim-order proceeds, snapshot-pool charges — moves
units between exactly four ledger accounts:

    free        the host pool (unowned, grantable now)
    granted     per-replica holdings (the VMs' plugged memory)
    escrow      reclaim-order proceeds drained by victims but not yet
                claimed by their requesters (in flight between VMs)
    snapshot    the host snapshot pool's charge (persisted warm-restart
                state, droppable under pressure)

and the invariant the whole test suite anchors on is checked in ONE
place, ``check``::

    free + sum(granted) + escrow + snapshot == budget

``HostMemoryBroker`` used to own these counters inline; extracting them
lets the fleet layer (``repro.cluster.fleet``) run N hosts with N
independent ledgers and assert per-host conservation after every fleet
event — including cross-host snapshot migrations, which are a
``snapshot_credit`` on the source ledger and a ``snapshot_charge`` on
the destination one, never a unit teleporting between budgets.

Each verb asserts its own preconditions (no negative balances, no
overdrafts), so an illegal flow fails loudly at the flow, not later at a
``check`` that can no longer say who leaked.
"""
from __future__ import annotations


class BudgetLedger:
    """Unit-conservation ledger for one host's memory budget."""

    def __init__(self, budget_units: int):
        assert budget_units > 0
        self.budget_units = budget_units
        self.free_units = budget_units
        self.granted: dict[str, int] = {}
        self.escrow_units = 0
        self.snapshot_units = 0

    # ------------------------------------------------------------- replicas
    def carve(self, replica_id: str, units: int) -> None:
        """Boot-time plug: carve a new replica's initial holding out of
        the free pool."""
        assert replica_id not in self.granted, replica_id
        assert 0 <= units <= self.free_units, \
            f"budget exhausted carving {units} for {replica_id}: " \
            f"free {self.free_units}"
        self.free_units -= units
        self.granted[replica_id] = units

    def take_free(self, replica_id: str, want: int) -> int:
        """Grant fill: move up to ``want`` units free -> granted.
        Clipped to the pool, never overdrafts; returns units moved."""
        assert replica_id in self.granted, replica_id
        take = min(max(want, 0), self.free_units)
        self.free_units -= take
        self.granted[replica_id] += take
        return take

    def release(self, replica_id: str, units: int) -> None:
        """Unplug completion: granted -> free."""
        assert 0 < units <= self.granted.get(replica_id, 0), \
            f"{replica_id} returning {units} units it was never granted"
        self.granted[replica_id] -= units
        self.free_units += units

    # --------------------------------------------------------------- escrow
    def escrow_fill(self, victim: str, units: int) -> None:
        """Order drain: a victim's surrendered units enter escrow (owned
        by an open grant, awaiting the requester's claim)."""
        assert 0 < units <= self.granted.get(victim, 0), (victim, units)
        self.granted[victim] -= units
        self.escrow_units += units

    def escrow_claim(self, replica_id: str, units: int) -> None:
        """Grant completion: escrow -> the requester's holding."""
        assert 0 < units <= self.escrow_units, (units, self.escrow_units)
        assert replica_id in self.granted, replica_id
        self.escrow_units -= units
        self.granted[replica_id] += units

    # ------------------------------------------------------------- snapshot
    def snapshot_charge(self, units: int) -> None:
        """Pool insert: free -> snapshot charge."""
        assert 0 < units <= self.free_units, (units, self.free_units)
        self.free_units -= units
        self.snapshot_units += units

    def snapshot_credit(self, units: int) -> None:
        """Pool drop/evict/squeeze: snapshot charge -> free.  A zero
        credit is a no-op (callers pass through ``pool.drop`` returns)."""
        if units == 0:
            return
        assert 0 < units <= self.snapshot_units, \
            (units, self.snapshot_units)
        self.snapshot_units -= units
        self.free_units += units

    # ------------------------------------------------------------ invariant
    def check(self) -> None:
        """THE conservation law — the one code path per host that proves
        no unit was leaked or double-granted."""
        assert self.free_units >= 0
        assert self.escrow_units >= 0
        assert self.snapshot_units >= 0
        assert all(g >= 0 for g in self.granted.values())
        assert self.free_units + sum(self.granted.values()) \
            + self.escrow_units + self.snapshot_units \
            == self.budget_units, "host units leaked or double-granted"
