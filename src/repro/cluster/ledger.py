"""Per-host budget ledger: the conservation law with a single owner.

Every host-level memory flow — boot-time plugs, grant fills, unplug
releases, escrowed reclaim-order proceeds, snapshot-pool charges — moves
units between exactly four ledger accounts:

    free        the host pool (unowned, grantable now)
    granted     per-replica holdings (the VMs' plugged memory)
    escrow      reclaim-order proceeds drained by victims but not yet
                claimed by their requesters (in flight between VMs)
    snapshot    the host snapshot pool's charge (persisted warm-restart
                state, droppable under pressure)

and the invariant the whole test suite anchors on is checked in ONE
place, ``check``::

    free + sum(granted) + escrow + snapshot == budget

``HostMemoryBroker`` used to own these counters inline; extracting them
lets the fleet layer (``repro.cluster.fleet``) run N hosts with N
independent ledgers and assert per-host conservation after every fleet
event — including cross-host snapshot migrations, which are a
``snapshot_credit`` on the source ledger and a ``snapshot_charge`` on
the destination one, never a unit teleporting between budgets.

Tenants: the ledger optionally splits the budget into per-tenant
sub-budgets (``tenants={name: units}``, summing exactly to the budget).
Every replica belongs to a tenant (``carve(..., tenant=)``), escrow
fills are attributed to the *requesting* grant's tenant, and snapshot
charges carry their owner tenant — so the host accounts are exactly the
tenant account sums and the conservation law extends to

    sum_over_tenants(free_t + granted_t + escrow_t + snapshot_t) == budget

where ``free_t = sub_budget_t - usage_t`` may go *negative* for a tenant
overdrawn into host slack (grants are work-conserving).  The fairness
rule built on these accounts lives broker-side: one tenant's grant can
squeeze another tenant's snapshots only while the owner stays at or
above its sub-budget afterwards (``HostMemoryBroker._squeeze_snapshots``).
Without an explicit ``tenants=`` map the ledger runs one implicit
``"default"`` tenant owning the whole budget, and every pre-tenant call
site behaves identically.

Each verb asserts its own preconditions (no negative balances, no
overdrafts), so an illegal flow fails loudly at the flow, not later at a
``check`` that can no longer say who leaked.
"""
from __future__ import annotations

from typing import Any, Optional

DEFAULT_TENANT = "default"


class BudgetLedger:
    """Unit-conservation ledger for one host's memory budget."""

    def __init__(self, budget_units: int,
                 tenants: Optional[dict[str, int]] = None):
        assert budget_units > 0
        self.budget_units = budget_units
        if tenants is None:
            tenants = {DEFAULT_TENANT: budget_units}
        assert tenants and all(v >= 0 for v in tenants.values()), tenants
        assert sum(tenants.values()) == budget_units, \
            f"tenant sub-budgets {tenants} must sum to budget {budget_units}"
        self.sub_budgets: dict[str, int] = dict(tenants)
        self.free_units = budget_units
        self.granted: dict[str, int] = {}
        self.escrow_units = 0
        self.snapshot_units = 0
        # tenant attribution: replicas map to tenants; escrow and snapshot
        # units carry their owning tenant explicitly (granted is derived
        # from the replica map, so it cannot diverge)
        self.tenant_of: dict[str, str] = {}
        self._tenant_escrow: dict[str, int] = {t: 0 for t in tenants}
        self._tenant_snapshot: dict[str, int] = {t: 0 for t in tenants}

    # -------------------------------------------------------------- tenants
    def resolve_tenant(self, tenant: Optional[str] = None) -> str:
        """Validate ``tenant``; ``None``/empty falls back to the sole
        tenant (an explicit name is required on multi-tenant ledgers)."""
        if tenant:
            assert tenant in self.sub_budgets, \
                f"unknown tenant {tenant!r} (have {sorted(self.sub_budgets)})"
            return tenant
        assert len(self.sub_budgets) == 1, \
            "multi-tenant ledger: an explicit tenant is required"
        return next(iter(self.sub_budgets))

    def tenant_granted(self, tenant: str) -> int:
        return sum(u for r, u in self.granted.items()
                   if self.tenant_of[r] == tenant)

    def tenant_escrow(self, tenant: str) -> int:
        return self._tenant_escrow[tenant]

    def tenant_snapshot(self, tenant: str) -> int:
        return self._tenant_snapshot[tenant]

    def tenant_usage(self, tenant: str) -> int:
        """Units the tenant currently holds across granted + escrow +
        snapshot (its footprint against its sub-budget)."""
        return self.tenant_granted(tenant) + self._tenant_escrow[tenant] \
            + self._tenant_snapshot[tenant]

    def tenant_free(self, tenant: str) -> int:
        """Sub-budget headroom; negative = overdrawn into host slack."""
        return self.sub_budgets[tenant] - self.tenant_usage(tenant)

    def tenant_report(self) -> dict[str, Any]:
        return {t: {"sub_budget": self.sub_budgets[t],
                    "granted": self.tenant_granted(t),
                    "escrow": self._tenant_escrow[t],
                    "snapshot": self._tenant_snapshot[t],
                    "free": self.tenant_free(t)}
                for t in sorted(self.sub_budgets)}

    # ------------------------------------------------------------- replicas
    def carve(self, replica_id: str, units: int,
              tenant: Optional[str] = None) -> None:
        """Boot-time plug: carve a new replica's initial holding out of
        the free pool, binding the replica to its tenant."""
        assert replica_id not in self.granted, replica_id
        assert 0 <= units <= self.free_units, \
            f"budget exhausted carving {units} for {replica_id}: " \
            f"free {self.free_units}"
        self.tenant_of[replica_id] = self.resolve_tenant(tenant)
        self.free_units -= units
        self.granted[replica_id] = units

    def take_free(self, replica_id: str, want: int) -> int:
        """Grant fill: move up to ``want`` units free -> granted.
        Clipped to the pool, never overdrafts; returns units moved."""
        assert replica_id in self.granted, replica_id
        take = min(max(want, 0), self.free_units)
        self.free_units -= take
        self.granted[replica_id] += take
        return take

    def release(self, replica_id: str, units: int) -> None:
        """Unplug completion: granted -> free."""
        assert 0 < units <= self.granted.get(replica_id, 0), \
            f"{replica_id} returning {units} units it was never granted"
        self.granted[replica_id] -= units
        self.free_units += units

    # --------------------------------------------------------------- escrow
    def escrow_fill(self, victim: str, units: int, *,
                    requester: Optional[str] = None) -> None:
        """Order drain: a victim's surrendered units enter escrow (owned
        by an open grant, awaiting the requester's claim).  The escrow is
        attributed to the *requester's* tenant — the grant owns those
        units now — falling back to the victim's tenant when no requester
        is named (direct ledger drives)."""
        assert 0 < units <= self.granted.get(victim, 0), (victim, units)
        owner = requester if requester in self.tenant_of else victim
        self.granted[victim] -= units
        self.escrow_units += units
        self._tenant_escrow[self.tenant_of[owner]] += units

    def escrow_claim(self, replica_id: str, units: int) -> None:
        """Grant completion: escrow -> the requester's holding."""
        assert 0 < units <= self.escrow_units, (units, self.escrow_units)
        assert replica_id in self.granted, replica_id
        t = self.tenant_of[replica_id]
        assert units <= self._tenant_escrow[t], \
            f"tenant {t} claiming {units} escrowed units it owns " \
            f"{self._tenant_escrow[t]} of"
        self.escrow_units -= units
        self._tenant_escrow[t] -= units
        self.granted[replica_id] += units

    # ------------------------------------------------------------- snapshot
    def snapshot_charge(self, units: int,
                        tenant: Optional[str] = None) -> None:
        """Pool insert: free -> snapshot charge, owned by ``tenant``."""
        assert 0 < units <= self.free_units, (units, self.free_units)
        self.free_units -= units
        self.snapshot_units += units
        self._tenant_snapshot[self.resolve_tenant(tenant)] += units

    def snapshot_credit(self, units: int,
                        tenant: Optional[str] = None) -> None:
        """Pool drop/evict/squeeze: snapshot charge -> free.  A zero
        credit is a no-op (callers pass through ``pool.drop`` returns)."""
        if units == 0:
            return
        assert 0 < units <= self.snapshot_units, \
            (units, self.snapshot_units)
        t = self.resolve_tenant(tenant)
        assert units <= self._tenant_snapshot[t], \
            f"tenant {t} crediting {units} snapshot units it owns " \
            f"{self._tenant_snapshot[t]} of"
        self.snapshot_units -= units
        self._tenant_snapshot[t] -= units
        self.free_units += units

    # ------------------------------------------------------------ invariant
    def check(self) -> None:
        """THE conservation law — the one code path per host that proves
        no unit was leaked or double-granted, host-wide AND per-tenant."""
        assert self.free_units >= 0
        assert self.escrow_units >= 0
        assert self.snapshot_units >= 0
        assert all(g >= 0 for g in self.granted.values())
        assert self.free_units + sum(self.granted.values()) \
            + self.escrow_units + self.snapshot_units \
            == self.budget_units, "host units leaked or double-granted"
        # tenant accounts sum exactly to the host accounts
        assert sum(self.sub_budgets.values()) == self.budget_units
        assert set(self.tenant_of.values()) <= set(self.sub_budgets)
        assert all(v >= 0 for v in self._tenant_escrow.values())
        assert all(v >= 0 for v in self._tenant_snapshot.values())
        assert sum(self._tenant_escrow.values()) == self.escrow_units, \
            "tenant escrow attribution diverged from the host account"
        assert sum(self._tenant_snapshot.values()) == self.snapshot_units, \
            "tenant snapshot attribution diverged from the host account"
        # free_t is derived (sub_budget - usage, may be negative for an
        # overdrawn tenant), so this sum is the real cross-check that the
        # per-tenant accounts partition the host budget exactly
        assert sum(self.tenant_free(t) for t in self.sub_budgets) \
            == self.free_units, "tenant free headroom diverged"
        assert sum(self.tenant_free(t) + self.tenant_granted(t)
                   + self._tenant_escrow[t] + self._tenant_snapshot[t]
                   for t in self.sub_budgets) == self.budget_units, \
            "tenant conservation law violated"
