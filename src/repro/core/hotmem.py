"""HotMem partition manager — the paper's contribution (§3–4), TPU-adapted.

Guest-physical memory -> the replica's state arena (leading axis of every
cache array).  One partition == one arena row == one request's entire decode
state, sized by the request-declared token budget.  The manager is the
host-side metadata plane (the kernel's zone structs): it never touches
device data.  Reclamation is therefore O(1) metadata with **zero
migrations** — the paper's key property.

Faithful mechanisms:
  * ``reserve``  — zonelist scan, lowest-index-first (keeps high rows free so
                   shrink rarely blocks); waitqueue when all partitions busy.
  * ``fork``     — children share the parent's partition (refcount
                   ``partition_users``).
  * ``release``  — refcount drop; at zero the partition returns to the free
                   list and the waitqueue head is woken.  Stale state is NOT
                   zeroed (paper: zeroing elided — the arena is re-zeroed
                   once on plug, by the "host").
  * ``plug`` / ``unplug`` — populate / drop whole partitions.  Unplug takes
                   only *empty* partitions (suffix-free, since arena rows are
                   a dense array — see DESIGN.md §5.1) and never migrates.
  * limit enforcement — ``grow`` beyond ``partition_tokens`` kills the
                   request (the paper's OOM-kill on partition overflow).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque
from typing import Optional

from repro.core.arena import ArenaSpec, ReclaimEvent


@dataclasses.dataclass
class _Binding:
    partition: int
    users: int                     # partition_users refcount
    tokens: int                    # occupancy within the budget


class HotMemManager:
    """Host metadata for a HotMem arena (one serving replica)."""

    def __init__(self, spec: ArenaSpec, plugged: Optional[int] = None):
        self.spec = spec
        self.max_partitions = spec.n_partitions     # concurrency factor N
        self.plugged = spec.n_partitions if plugged is None else plugged
        self._free: list[int] = list(range(self.plugged))   # min-heap
        heapq.heapify(self._free)
        self._bindings: dict[str, _Binding] = {}            # req -> binding
        self._owner: dict[int, str] = {}                    # partition -> req
        self.waitqueue: deque[str] = deque()
        # --- counters (benchmarks read these) ---
        self.reclaim_events: list[ReclaimEvent] = []
        self.bytes_zeroed = 0
        self.kills = 0

    # ------------------------------------------------------------- queries
    @property
    def free_partitions(self) -> int:
        return len(self._free)

    @property
    def live_partitions(self) -> int:
        return self.plugged - len(self._free)

    def partition_of(self, req: str) -> Optional[int]:
        b = self._bindings.get(req)
        return b.partition if b else None

    def occupancy(self) -> float:
        return self.live_partitions / max(self.plugged, 1)

    # ------------------------------------------------------------ reserve
    def reserve(self, req: str) -> Optional[int]:
        """Bind ``req`` to the lowest free partition; None -> waitqueued."""
        assert req not in self._bindings, req
        if not self._free:
            if req not in self.waitqueue:
                self.waitqueue.append(req)
            return None
        p = heapq.heappop(self._free)
        self._bindings[req] = _Binding(partition=p, users=1, tokens=0)
        self._owner[p] = req
        return p

    def fork(self, req: str) -> int:
        """clone(): child shares the parent's partition (refcount++)."""
        b = self._bindings[req]
        b.users += 1
        return b.partition

    def adopt(self, old: str, new: str) -> int:
        """Warm reuse: rebind a kept-alive partition to a new request
        (zero data movement; token accounting restarts)."""
        b = self._bindings.pop(old)
        b.tokens = 0
        self._bindings[new] = b
        self._owner[b.partition] = new
        return b.partition

    def grow(self, req: str, n_tokens: int) -> bool:
        """Account token growth; False => budget exceeded, request killed
        (the paper's OOM-kill keeps partition isolation inviolable)."""
        b = self._bindings[req]
        b.tokens += n_tokens
        if b.tokens > self.spec.partition_tokens:
            self.kills += 1
            self.release(req, force=True)
            return False
        return True

    def release(self, req: str, force: bool = False) -> Optional[str]:
        """Refcount drop; at zero the partition frees (NO data movement, NO
        zeroing) and the waitqueue head is woken.  Returns the woken req."""
        b = self._bindings[req]
        b.users -= 1
        if b.users > 0 and not force:
            return None
        del self._bindings[req]
        del self._owner[b.partition]
        heapq.heappush(self._free, b.partition)
        if self.waitqueue:
            return self.waitqueue.popleft()
        return None

    # -------------------------------------------------------- plug/unplug
    def plug(self, k: int) -> int:
        """Populate up to ``k`` partitions (hypervisor plug request).  New
        partitions are zeroed once here (init_on_alloc elided thereafter)."""
        k = min(k, self.max_partitions - self.plugged)
        for p in range(self.plugged, self.plugged + k):
            heapq.heappush(self._free, p)
        self.plugged += k
        self.bytes_zeroed += k * self.spec.bytes_per_partition
        return k

    def shrink_plan(self, k: int) -> list[int]:
        """Partitions an unplug of ``k`` may drop *right now*: the dense-
        array analogue requires a free suffix; lowest-first allocation keeps
        live rows packed at the bottom, so the suffix is normally free."""
        drop = []
        p = self.plugged - 1
        free = set(self._free)
        while p >= 0 and len(drop) < k and p in free:
            drop.append(p)
            p -= 1
        return drop

    def unplug(self, k: int) -> ReclaimEvent:
        """Partition-aware unplug: drop empty partitions, zero migrations.
        Wall time is pure metadata cost — measured, not asserted."""
        t0 = time.perf_counter()
        drop = self.shrink_plan(k)
        for p in drop:
            self._free.remove(p)
        heapq.heapify(self._free)
        self.plugged -= len(drop)
        ev = ReclaimEvent(
            requested_units=k, reclaimed_units=len(drop),
            reclaimed_bytes=len(drop) * self.spec.bytes_per_partition,
            migrated_blocks=0, migrated_bytes=0,
            wall_seconds=time.perf_counter() - t0)
        self.reclaim_events.append(ev)
        return ev

    # ----------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        free = set(self._free)
        live = set(self._owner)
        assert free.isdisjoint(live)
        assert free | live == set(range(self.plugged)) - (
            set() if len(free | live) == self.plugged else set())
        assert len(free) + len(live) == self.plugged
        for req, b in self._bindings.items():
            assert self._owner[b.partition] == req
            assert b.users >= 1
            assert 0 <= b.tokens <= self.spec.partition_tokens
