"""Arena geometry: the shared vocabulary of the HotMem and vanilla managers.

An *arena* is the device-memory region holding per-request decode state
(KV caches and/or SSM/LRU state) for one serving replica.  HotMem divides it
into ``n_partitions`` fixed-size partitions of ``partition_tokens`` (the
request-declared token budget — the paper's user-declared function memory
limit).  The vanilla baseline divides the same capacity into blocks of
``block_tokens`` (the analogue of Linux's 128 MiB memory blocks).
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ModelConfig


def state_bytes_for_tokens(cfg: ModelConfig, tokens: int) -> int:
    """Device bytes of per-request decode state at a given context length
    (sums the cache spec tree for batch=1; window caches cap at the window,
    SSM/LRU state is constant — exactly what a partition must hold)."""
    from repro.models.model import cache_specs
    from repro.models.layers import tree_map_specs
    total = 0

    def acc(spec):
        nonlocal total
        import numpy as np
        total += math.prod(spec.shape) * np.dtype(spec.dtype).itemsize

    tree_map_specs(acc, cache_specs(cfg, 1, max(tokens, 1)))
    return total


@dataclasses.dataclass(frozen=True)
class ArenaSpec:
    """Geometry of one replica's state arena."""
    partition_tokens: int          # request-declared budget (paper: mem limit)
    n_partitions: int              # concurrency factor N (paper: boot param)
    block_tokens: int = 128        # vanilla granularity (paper: 128MiB block)
    bytes_per_partition: int = 0   # device bytes of one partition

    @property
    def blocks_per_partition(self) -> int:
        return math.ceil(self.partition_tokens / self.block_tokens)

    @property
    def n_blocks(self) -> int:     # same total capacity for both managers
        return self.n_partitions * self.blocks_per_partition

    @property
    def bytes_per_block(self) -> int:
        return math.ceil(self.bytes_per_partition
                         / self.blocks_per_partition)

    @property
    def arena_bytes(self) -> int:
        return self.bytes_per_partition * self.n_partitions

    @classmethod
    def from_model(cls, cfg: ModelConfig, partition_tokens: int,
                   n_partitions: int, block_tokens: int = 128) -> "ArenaSpec":
        return cls(partition_tokens=partition_tokens,
                   n_partitions=n_partitions,
                   block_tokens=block_tokens,
                   bytes_per_partition=state_bytes_for_tokens(
                       cfg, partition_tokens))


@dataclasses.dataclass
class ReclaimEvent:
    """Outcome of one shrink/unplug request (the paper's unplug metric)."""
    requested_units: int           # partitions (hotmem) or blocks (vanilla)
    reclaimed_units: int
    reclaimed_bytes: int
    migrated_blocks: int           # 0 for HotMem by construction
    migrated_bytes: int
    wall_seconds: float = 0.0
