"""Elastic arena: bucket ladder + the device-side data plane.

JAX arrays are static-shaped, so VM resize (virtio-mem plug/unplug) becomes
a ladder of AOT-compiled arena sizes.  Moving *down* the ladder is where the
two managers diverge — the paper's entire point:

  * HotMem: live partitions are whole rows; shrink = contiguous prefix
    truncation (plus O(1) metadata).  Zero gathers, zero migrations.
  * Vanilla: live blocks are scattered; shrink must first run a migration
    pass (``apply_migrations`` — gather+scatter device copies), then
    truncate.  Copy bytes grow with occupancy.

Both paths are real jitted device ops so benchmarks measure actual copies,
not a model of them.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arena import ArenaSpec, ReclaimEvent
from repro.core.hotmem import HotMemManager
from repro.core.vanilla import VanillaPagedManager

# ---------------------------------------------------------------------------
# Bucket ladder
# ---------------------------------------------------------------------------


def bucket_ladder(max_units: int, min_units: int = 1,
                  factor: float = 2.0) -> list[int]:
    """Geometric ladder of arena sizes (in partitions/blocks), ascending."""
    sizes = {max_units}
    u = max_units
    while u > min_units:
        u = max(min_units, int(u / factor))
        sizes.add(u)
    return sorted(sizes)


def target_bucket(ladder: list[int], demand: int) -> int:
    """Smallest bucket covering current demand (with its own headroom)."""
    for b in ladder:
        if b >= demand:
            return b
    return ladder[-1]


# ---------------------------------------------------------------------------
# Device-side data plane (jitted; shapes static per (rows, move-capacity))
# ---------------------------------------------------------------------------


@jax.jit
def zero_rows(caches, lo: jax.Array, count: jax.Array):
    """Zero arena rows [lo, lo+count) — plug-time zero-fill (zeroing is
    elided on the reclaim path, per the paper)."""
    def z(x):
        idx = jnp.arange(x.shape[0])
        m = (idx >= lo) & (idx < lo + count)
        return jnp.where(m.reshape((-1,) + (1,) * (x.ndim - 1)), 0, x)
    return jax.tree.map(z, caches)


def slice_rows(caches, new_rows: int):
    """HotMem bucket-shrink: contiguous prefix truncation (no gathers)."""
    return jax.tree.map(lambda x: x[:new_rows], caches)


def grow_rows(caches, new_rows: int):
    """Bucket-grow: extend the leading axis with zeroed rows."""
    def g(x):
        pad = [(0, new_rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, pad)
    return jax.tree.map(g, caches)


@jax.jit
def apply_migrations(pool, src: jax.Array, dst: jax.Array, count: jax.Array):
    """Vanilla migration pass: pool[dst[i]] = pool[src[i]] for i < count.
    src/dst are fixed-capacity int32 vectors (padded with identity moves)
    so one compiled executable serves every shrink event."""
    idx = jnp.arange(src.shape[0])
    live = idx < count
    safe_src = jnp.where(live, src, 0)

    def mig(x):
        oob = x.shape[0]                      # dead slots scatter out of range
        sdst = jnp.where(live, dst, oob)
        return x.at[sdst].set(x[safe_src], mode="drop")
    return jax.tree.map(mig, pool)


def pool_rows(pool) -> int:
    return jax.tree.leaves(pool)[0].shape[0]


def gather_blocks(pool, tables: jax.Array):
    """Paged read: (NB, BT, ...) pool + (P, max_blocks) tables ->
    (P, max_blocks*BT, ...) row-contiguous view.  This is the per-step
    gather the vanilla layout pays (fused by the Pallas paged kernel on
    TPU); HotMem's contiguous rows skip it entirely."""
    def g(x):
        bt = x.shape[1]
        out = x[jnp.maximum(tables, 0)]             # (P, MB, BT, ...)
        out = jnp.where(
            (tables >= 0).reshape(tables.shape + (1,) * (x.ndim - 1)),
            out, 0)
        return out.reshape((tables.shape[0], tables.shape[1] * bt)
                           + x.shape[2:])
    return jax.tree.map(g, pool)


def scatter_blocks(pool, rows, tables: jax.Array):
    """Write row-layout updates back into the pool through the tables."""
    def s(x, r):
        bt = x.shape[1]
        r = r.reshape((tables.shape[0], tables.shape[1], bt) + x.shape[2:])
        flat_idx = jnp.maximum(tables, 0).reshape(-1)
        upd = r.reshape((-1, bt) + x.shape[2:])
        keep = (tables >= 0).reshape(-1)
        upd = jnp.where(keep.reshape((-1,) + (1,) * (upd.ndim - 1)),
                        upd, x[flat_idx])
        return x.at[flat_idx].set(upd)
    return jax.tree.map(s, pool, rows)


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


class ElasticArena:
    """One replica's arena: manager (metadata) + optional device cache tree.

    ``mode``: "hotmem" | "vanilla" | "static" (statically over-provisioned —
    the paper's third comparison point: never resizes).

    ``grant`` / ``release`` are the host gate (virtio-mem): when set,
    growth is a *request* — ``grant(units)`` returns how many units the
    host actually concedes (possibly zero, possibly after shrinking an
    idler replica) — and every unit this arena drops flows back through
    ``release``.  Without them the arena resizes unilaterally, the
    pre-broker single-replica behavior.
    """

    MOVE_CAPACITY = 256      # padded migration vector (one executable)

    def __init__(self, cfg, spec: ArenaSpec, mode: str, caches=None,
                 seed: int = 0, grant=None, release=None):
        self.cfg = cfg
        self.spec = spec
        self.mode = mode
        self.caches = caches
        if mode == "vanilla":
            self.manager = VanillaPagedManager(spec, seed=seed)
        else:
            self.manager = HotMemManager(spec)
        self._grant: Optional[Callable[[int], int]] = grant
        self._release: Optional[Callable[[int], None]] = release
        self.plug_seconds: list[float] = []

    # ------------------------------------------------------------ lifecycle
    def admit(self, req: str):
        return self.manager.reserve(req)

    def on_tokens(self, req: str, n: int) -> bool:
        r = self.manager.grow(req, n)
        return r is not None and r is not False

    def finish(self, req: str):
        return self.manager.release(req)

    # ------------------------------------------------------------- elastic
    def units(self) -> int:
        if self.mode == "vanilla":
            return self.manager.pool_blocks
        return self.manager.plugged

    def plug(self, units: int) -> float:
        """Grow the arena; returns wall seconds (incl. zero-fill).  With a
        host gate, ``units`` is a request — the host grants what it can
        (stealing from an idler replica under pressure, or issuing async
        reclaim orders whose fills arrive later via ``absorb``) and any
        grant the manager can't absorb flows straight back."""
        if self.mode == "static":
            return 0.0
        if self._grant is not None:
            units = self._grant(units)
        return self.absorb(units)

    def absorb(self, units: int, shards: int = 1) -> float:
        """Grant-completion path: absorb ``units`` the host has *already*
        delivered (an async ``Grant`` fill the engine claimed), skipping
        the host gate — requesting again would double-order.  Same device
        work as ``plug``: grow rows, zero-fill, hand back any units the
        manager can't take.  On a sharded host the delivered units are a
        whole stripe — ``shards`` slabs land one per device, so the count
        must divide evenly (the broker's coherent-claim path guarantees
        it; a bare partial stripe here is a caller bug)."""
        assert shards >= 1 and units % shards == 0, \
            f"absorb of {units} units is not a whole {shards}-shard stripe"
        if units <= 0 or self.mode == "static":
            return 0.0
        t0 = time.perf_counter()
        old = self.units()
        added = self.manager.plug(units)
        if self._release is not None and units > added:
            self._release(units - added)      # manager clamped; hand back
        if added and self.caches is not None:
            self.caches = grow_rows(self.caches, old + added)
            self.caches = zero_rows(self.caches, jnp.asarray(old),
                                    jnp.asarray(added))
            jax.block_until_ready(jax.tree.leaves(self.caches)[0])
        dt = time.perf_counter() - t0
        self.plug_seconds.append(dt)
        return dt

    def unplug(self, units: int, notify_host: bool = True) -> ReclaimEvent:
        """Shrink the arena; HotMem = metadata + prefix slice, vanilla =
        migration copies + prefix slice.  Real device timings.  Reclaimed
        units flow back to the host gate unless ``notify_host`` is False
        (the broker-initiated steal path, which does its own accounting)."""
        assert self.mode != "static"
        t0 = time.perf_counter()
        if self.mode == "hotmem":
            ev = self.manager.unplug(units)
            if ev.reclaimed_units and self.caches is not None:
                self.caches = slice_rows(self.caches, self.manager.plugged)
                jax.block_until_ready(jax.tree.leaves(self.caches)[0])
            ev.wall_seconds = time.perf_counter() - t0
            if notify_host and self._release is not None \
                    and ev.reclaimed_units:
                self._release(ev.reclaimed_units)
            return ev
        # vanilla: plan migrations, run copies, then commit + truncate
        k, moves = self.manager.shrink_plan(units)
        copy_s = 0.0
        if self.caches is not None and moves:
            nmov = len(moves)
            cap = max(self.MOVE_CAPACITY,
                      ((nmov + 255) // 256) * 256)
            src = np.zeros(cap, np.int32)
            dst = np.full(cap, pool_rows(self.caches), np.int32)
            src[:nmov] = [m[0] for m in moves]
            dst[:nmov] = [m[1] for m in moves]
            tc = time.perf_counter()
            self.caches = apply_migrations(self.caches, jnp.asarray(src),
                                           jnp.asarray(dst),
                                           jnp.asarray(nmov))
            jax.block_until_ready(jax.tree.leaves(self.caches)[0])
            copy_s = time.perf_counter() - tc
        ev = self.manager.apply_shrink(k, moves, copy_seconds=copy_s)
        if k and self.caches is not None:
            self.caches = jax.tree.map(
                lambda x: x[:self.manager.pool_blocks], self.caches)
            jax.block_until_ready(jax.tree.leaves(self.caches)[0])
        ev.wall_seconds = time.perf_counter() - t0
        if notify_host and self._release is not None and ev.reclaimed_units:
            self._release(ev.reclaimed_units)
        return ev
