"""Vanilla paged baseline — the state-of-practice the paper measures against.

Requests allocate KV blocks lazily from a shared pool as their context grows
(the guest OS's lazy page-fault allocation).  The allocator hands out *any*
free block, so concurrent requests' footprints interleave across the pool
(paper Fig. 2).  Releasing a request frees scattered blocks; shrinking the
pool then requires **migrating** live blocks out of the tail being dropped —
real device copies (``kv_compact``) whose cost grows with occupancy and
which steal HBM bandwidth from concurrently decoding requests.  That cost is
exactly what HotMem eliminates.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Optional

from repro.core.arena import ArenaSpec, ReclaimEvent


class VanillaPagedManager:
    """Block-table manager over a shared block pool (host metadata)."""

    def __init__(self, spec: ArenaSpec, seed: int = 0,
                 pool_blocks: Optional[int] = None):
        self.spec = spec
        self.pool_blocks = spec.n_blocks if pool_blocks is None else \
            pool_blocks
        self._rng = random.Random(seed)
        self._free: list[int] = list(range(self.pool_blocks))
        self._rng.shuffle(self._free)          # interleaved hand-out order
        self._tables: dict[str, list[int]] = {}
        self._tokens: dict[str, int] = {}
        self.waitqueue: list[str] = []
        self.reclaim_events: list[ReclaimEvent] = []
        self.kills = 0

    # ------------------------------------------------------------- queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return self.pool_blocks - len(self._free)

    def occupancy(self) -> float:
        return self.live_blocks / max(self.pool_blocks, 1)

    def block_table(self, req: str) -> list[int]:
        return self._tables[req]

    # ------------------------------------------------------------ allocate
    def reserve(self, req: str) -> Optional[int]:
        """Admission: start a request (no blocks yet — lazy)."""
        if req in self._tables:
            return 0
        # admission control mirrors HotMem's: capacity for one full budget
        if (self.free_blocks < self.spec.blocks_per_partition
                or len(self._tables) >= self.spec.n_partitions):
            if req not in self.waitqueue:
                self.waitqueue.append(req)
            return None
        self._tables[req] = []
        self._tokens[req] = 0
        return 0

    def grow(self, req: str, n_tokens: int) -> Optional[list[int]]:
        """Lazy block allocation as the context grows (page faults).
        Returns newly allocated block ids, or None when killed (budget)."""
        self._tokens[req] += n_tokens
        if self._tokens[req] > self.spec.partition_tokens:
            self.kills += 1
            self.release(req)
            return None
        need = -(-self._tokens[req] // self.spec.block_tokens)
        new = []
        while len(self._tables[req]) < need:
            if not self._free:
                return new        # pool exhausted; caller must plug
            new.append(self._free.pop())
            self._tables[req].append(new[-1])
        return new

    def adopt(self, old: str, new: str) -> int:
        """Warm reuse: hand a kept-alive request's blocks to a new one."""
        self._tables[new] = self._tables.pop(old)
        self._tokens.pop(old, None)
        self._tokens[new] = 0
        return 0

    def release(self, req: str) -> Optional[str]:
        """Free a request's (scattered) blocks."""
        blocks = self._tables.pop(req, [])
        self._tokens.pop(req, None)
        self._free.extend(blocks)
        self._rng.shuffle(self._free)         # keep hand-out interleaved
        if self.waitqueue:
            return self.waitqueue.pop(0)
        return None

    # -------------------------------------------------------- plug/unplug
    def plug(self, k_blocks: int) -> int:
        k = min(k_blocks, self.spec.n_blocks - self.pool_blocks)
        new = list(range(self.pool_blocks, self.pool_blocks + k))
        self.pool_blocks += k
        self._free.extend(new)
        self._rng.shuffle(self._free)
        return k

    def shrink_plan(self, k_blocks: int) -> tuple[int, list[tuple[int, int]]]:
        """To drop the tail ``k_blocks``, live blocks in the tail must
        migrate into free head slots.  Returns (achievable_k, [(src, dst)])
        — the migration list whose cost HotMem avoids entirely."""
        target = self.pool_blocks - k_blocks
        tail_live = [b for t in self._tables.values() for b in t
                     if b >= target]
        head_free = sorted(b for b in self._free if b < target)
        if len(tail_live) > len(head_free):   # cannot fully evacuate:
            # partial offline — evacuate only the deepest evacuable blocks
            tail_live = sorted(tail_live, reverse=True)[:len(head_free)]
        moves = list(zip(sorted(tail_live, reverse=True), head_free))
        # achievable shrink: largest suffix free after the moves
        occupied = set(b for t in self._tables.values() for b in t)
        occupied -= {s for s, _ in moves}
        occupied |= {d for _, d in moves}
        new_top = self.pool_blocks
        while new_top - 1 >= 0 and (new_top - 1) not in occupied:
            new_top -= 1
        k = min(k_blocks, self.pool_blocks - new_top)
        return k, moves

    def apply_shrink(self, k: int, moves: list[tuple[int, int]],
                     copy_seconds: float = 0.0) -> ReclaimEvent:
        """Commit a shrink after the device copies ran (caller timed them)."""
        t0 = time.perf_counter()
        remap = dict(moves)
        for req, table in self._tables.items():
            self._tables[req] = [remap.get(b, b) for b in table]
        target = self.pool_blocks - k
        dsts = {d for _, d in moves}
        self._free = [b for b in self._free if b not in dsts]
        self._free.extend(s for s, _ in moves)      # vacated sources
        self._free = [b for b in self._free if b < target]
        self.pool_blocks = target
        ev = ReclaimEvent(
            requested_units=k, reclaimed_units=k,
            reclaimed_bytes=k * self.spec.bytes_per_block,
            migrated_blocks=len(moves),
            migrated_bytes=len(moves) * self.spec.bytes_per_block,
            wall_seconds=(time.perf_counter() - t0) + copy_seconds)
        self.reclaim_events.append(ev)
        return ev

    # ----------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        live = [b for t in self._tables.values() for b in t]
        assert len(set(live)) == len(live), "block double-booked"
        assert set(live).isdisjoint(self._free)
        assert set(live) | set(self._free) == set(range(self.pool_blocks))
        for req, tok in self._tokens.items():
            need = -(-tok // self.spec.block_tokens)
            # never over-allocated; may be UNDER-allocated while the pool
            # is exhausted (lazy faults stall until the runtime plugs)
            assert len(self._tables[req]) <= need
