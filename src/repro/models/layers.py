"""Shared model building blocks: parameter specs, norms, projections, RoPE.

Parameters are plain nested dicts of arrays.  ``ParamSpec`` leaves (shape,
dtype, logical axes, init tag) are the single source of truth: the same spec
tree materializes real arrays for tests or sharded ``ShapeDtypeStruct`` trees
for the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import named_sharding, shard

bf16 = jnp.bfloat16
f32 = jnp.float32

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dtype: Any = bf16
    axes: tuple[Optional[str], ...] = ()
    init: str = "normal"        # normal | zeros | ones | a_log | dt_bias
    scale: float = 1.0          # stddev multiplier for "normal"

    def __iter__(self):         # (shape, dtype, axes) tuple-compat
        return iter((self.shape, self.dtype, self.axes))


def is_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    if isinstance(tree, ParamSpec):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: tree_map_specs(fn, v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(tree_map_specs(fn, v) for v in tree)
    raise TypeError(type(tree))


def stack_specs(tree, n: int):
    """Prepend a scanned-layer dimension of size ``n`` to every leaf."""
    return tree_map_specs(
        lambda s: dataclasses.replace(
            s, shape=(n,) + s.shape, axes=("layers",) + s.axes), tree)


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    # zeros/ones leaves are computed (not jnp constants) so every leaf is a
    # DISTINCT device buffer: jax dedupes identical constant arrays, and a
    # param tree with shared buffers cannot be donated to a train step.
    if spec.init == "zeros":
        return jnp.full(spec.shape, 0, spec.dtype) + jnp.zeros((), spec.dtype)
    if spec.init == "ones":
        return jnp.full(spec.shape, 1, spec.dtype) + jnp.zeros((), spec.dtype)
    if spec.init == "a_log":    # mamba2: A in [1, 16], store log
        u = jax.random.uniform(key, spec.shape, f32, 1.0, 16.0)
        return jnp.log(u).astype(spec.dtype)
    if spec.init == "dt_bias":  # mamba2: softplus^-1(dt), dt in [1e-3, 0.1]
        dt = jnp.exp(jax.random.uniform(key, spec.shape, f32)
                     * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(spec.dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, f32) * std).astype(spec.dtype)


def materialize(specs, rng: Optional[jax.Array] = None, *, abstract=False,
                mesh=None, rules=None):
    """Specs -> real arrays (rng given) or ShapeDtypeStructs (abstract)."""
    leaves = []
    tree_map_specs(leaves.append, specs)
    if abstract:
        def mk(s: ParamSpec):
            sh = (named_sharding(s.axes, s.shape, mesh, rules)
                  if mesh is not None else None)
            return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
        return tree_map_specs(mk, specs)
    keys = iter(jax.random.split(rng, max(len(leaves), 1)))
    return tree_map_specs(lambda s: _init_leaf(s, next(keys)), specs)


def sharding_tree(specs, mesh, rules):
    """NamedSharding tree matching a materialized param tree."""
    return tree_map_specs(
        lambda s: named_sharding(s.axes, s.shape, mesh, rules), specs)


# ---------------------------------------------------------------------------
# Primitive layers (functional; params are dict slices)
# ---------------------------------------------------------------------------


import contextvars as _cv
import contextlib as _cl

# int8 weight quantization for serving (beyond-paper perf lever): projection
# weights are stored int8 + per-output-channel scale; the dequant multiply
# fuses into the MXU matmul on TPU, so HBM weight traffic halves.
_QUANT = _cv.ContextVar("weight_quant", default=False)


@_cl.contextmanager
def weight_quant():
    tok = _QUANT.set(True)
    try:
        yield
    finally:
        _QUANT.reset(tok)


def dense_spec(d_in: int, d_out: int, axes, *, bias=False, dtype=bf16,
               scale=1.0):
    if _QUANT.get():
        out = {"w": ParamSpec((d_in, d_out), jnp.int8, axes, init="zeros"),
               "qscale": ParamSpec((d_out,), f32, (axes[-1],),
                                   init="ones")}
    else:
        out = {"w": ParamSpec((d_in, d_out), dtype, axes, scale=scale)}
    if bias:
        out["b"] = ParamSpec((d_out,), dtype, (axes[-1],), init="zeros")
    return out


def dense(p, x: jax.Array) -> jax.Array:
    w = p["w"]
    if w.dtype == jnp.int8:
        w = w.astype(x.dtype) * p["qscale"].astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"]
    return y


def norm_spec(d: int, axes=(None,)):
    return {"scale": ParamSpec((d,), f32, axes, init="ones")}


def rmsnorm(p, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(f32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def act_fn(name: str, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x) if name == "gelu" else jax.nn.silu(x)


def mlp_spec(cfg):
    return {
        "gate": dense_spec(cfg.d_model, cfg.d_ff, ("w_embed", "mlp")),
        "up": dense_spec(cfg.d_model, cfg.d_ff, ("w_embed", "mlp")),
        "down": dense_spec(cfg.d_ff, cfg.d_model, ("mlp", "w_embed")),
    }


def mlp(cfg, p, x: jax.Array) -> jax.Array:
    h = act_fn(cfg.act, dense(p["gate"], x)) * dense(p["up"], x)
    h = shard(h, "batch", "seq", "mlp")
    return dense(p["down"], h)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap else x


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=f32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple[int, ...] = ()) -> jax.Array:
    """x: (B, S, H, D).  positions: (B, S) or (3, B, S) for M-RoPE."""
    d2 = x.shape[-1] // 2
    freqs = _rope_freqs(x.shape[-1], theta)                    # (D/2,)
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        assert sum(mrope_sections) == d2
        sec = []
        start = 0
        for i, n in enumerate(mrope_sections):
            ang = positions[i][..., None].astype(f32) * freqs[start:start + n]
            sec.append(ang)
            start += n
        angles = jnp.concatenate(sec, axis=-1)                 # (B, S, D/2)
    else:
        angles = positions[..., None].astype(f32) * freqs      # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :d2].astype(f32), x[..., d2:].astype(f32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Vocabulary padding + loss
# ---------------------------------------------------------------------------

VOCAB_PAD = 2048


def padded_vocab(cfg) -> int:
    v = cfg.vocab_size
    if v % 16 == 0:          # evenly shardable over the 16-way "model" axis
        return v
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def vocab_logit_bias(cfg) -> Optional[np.ndarray]:
    """-inf bias on padded vocab entries (None when unpadded)."""
    vp = padded_vocab(cfg)
    if vp == cfg.vocab_size:
        return None
    bias = np.zeros((vp,), np.float32)
    bias[cfg.vocab_size:] = -1e9
    return bias


def cross_entropy(cfg, logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; logits (B, S, Vp) possibly vocab-padded/softcapped."""
    logits = softcap(logits, cfg.final_logit_softcap).astype(f32)
    bias = vocab_logit_bias(cfg)
    if bias is not None:
        logits = logits + bias
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
