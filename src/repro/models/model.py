"""Top-level model API: param/cache specs, init, and the three entry points
(train forward, prefill, decode step) for every assigned architecture.

Params and caches are plain nested dicts; specs (``ParamSpec`` trees) are the
single source of truth, materialized as real arrays (tests, examples) or as
sharded ``ShapeDtypeStruct`` trees (multi-pod dry-run — no allocation).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import materialize, sharding_tree
from repro.sharding import shard


# ---------------------------------------------------------------------------
# Specs / init
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig):
    specs: dict[str, Any] = {"embed": transformer.embed_specs(cfg)}
    if cfg.family == "audio":
        specs["encoder"] = encdec.encoder_specs(cfg)
        specs["decoder"] = encdec.decoder_specs(cfg)
    else:
        specs["decoder"] = transformer.decoder_specs(cfg)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    if cfg.family == "audio":
        return encdec.dec_cache_specs(cfg, batch, cache_len)
    return transformer.decoder_cache_specs(cfg, batch, cache_len)


def init_params(cfg: ModelConfig, rng: jax.Array):
    return materialize(param_specs(cfg), rng)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                rng: Optional[jax.Array] = None):
    import jax.random as jr
    return materialize(cache_specs(cfg, batch, cache_len),
                       rng if rng is not None else jr.PRNGKey(0))


def abstract_params(cfg: ModelConfig, mesh=None, rules=None):
    return materialize(param_specs(cfg), abstract=True, mesh=mesh,
                       rules=rules)


def abstract_caches(cfg: ModelConfig, batch: int, cache_len: int, mesh=None,
                    rules=None):
    return materialize(cache_specs(cfg, batch, cache_len), abstract=True,
                       mesh=mesh, rules=rules)


def param_shardings(cfg: ModelConfig, mesh, rules):
    return sharding_tree(param_specs(cfg), mesh, rules)


def cache_shardings(cfg: ModelConfig, batch: int, cache_len: int, mesh,
                    rules):
    return sharding_tree(cache_specs(cfg, batch, cache_len), mesh, rules)


# ---------------------------------------------------------------------------
# Row-wise cache utilities (the batch/partition axis is 1 inside scanned
# group stacks — leading axis is n_groups — and 0 in unscanned tail blocks)
# ---------------------------------------------------------------------------


def cache_axis_map(caches, fn):
    """Apply fn(leaf, batch_axis) across a cache tree."""
    out = {}
    for key, sub in caches.items():
        ax = 0 if key == "tail" else 1          # groups/blocks are stacked
        out[key] = jax.tree.map(lambda x, _ax=ax: fn(x, _ax), sub)
    return out


def cache_slice_rows(caches, rows: int):
    return cache_axis_map(
        caches, lambda x, ax: jax.lax.slice_in_dim(x, 0, rows, axis=ax))


def cache_grow_rows(caches, rows: int):
    def g(x, ax):
        pad = [(0, 0)] * x.ndim
        pad[ax] = (0, rows - x.shape[ax])
        return jnp.pad(x, pad)
    return cache_axis_map(caches, g)


def cache_num_rows(caches) -> int:
    for key, sub in caches.items():
        for leaf in jax.tree.leaves(sub):
            return leaf.shape[0 if key == "tail" else 1]
    raise ValueError("empty cache tree")


def cache_read_row(caches, row: int):
    """Gather arena row ``row`` out as a single-request cache (batch==1) —
    the readout twin of ``cache_write_row``.  The snapshot copy-out path
    pays this (then ``device_get``s the result to host memory), so the
    bytes it touches are the realistic persist cost."""
    out = {}
    for key, sub in caches.items():
        if key == "tail":
            out[key] = jax.tree.map(lambda c: c[row:row + 1], sub)
        else:
            out[key] = jax.tree.map(lambda c: c[:, row:row + 1], sub)
    return out


def cache_write_row(caches, row_caches, row: int):
    """Scatter a single-request cache (batch==1) into arena row ``row``."""
    out = {}
    for key, sub in caches.items():
        if key == "tail":
            out[key] = jax.tree.map(lambda c, r: c.at[row].set(r[0]),
                                    sub, row_caches[key])
        else:
            out[key] = jax.tree.map(lambda c, r: c.at[:, row].set(r[:, 0]),
                                    sub, row_caches[key])
    return out


# ---------------------------------------------------------------------------
# Fused row staging (snapshot data plane): whole rows move as ONE flat blob
# through one kernel launch, instead of one dispatch per leaf
# ---------------------------------------------------------------------------


def cache_flat_axes(caches):
    """Flat cache leaves + their batch axes, in tree-flatten order.
    Returns (leaves, axes, treedef)."""
    leaves, treedef = jax.tree.flatten(caches)
    axes = jax.tree.leaves(cache_axis_map(caches, lambda x, ax: ax))
    return leaves, axes, treedef


def cache_row_layout(caches):
    """Static ``RowLayout`` of this cache tree's per-row staging blob.
    Row-slice shapes are independent of the arena row count, so one
    layout stays valid across every bucket of the ladder."""
    from repro.kernels.kv_snapshot import build_layout
    leaves, axes, _ = cache_flat_axes(caches)
    return build_layout(leaves, axes)


def cache_read_rows(caches, rows, *, layout=None, impl="pallas"):
    """Batched twin of ``cache_read_row``: gather arena rows ``rows`` of
    EVERY leaf into one contiguous (N, row_elems) staging blob in a single
    fused launch.  The blob's byte image per row equals the leaf-order
    ``tobytes()`` concatenation the paginator hashes."""
    from repro.kernels import ops
    leaves, _axes, _ = cache_flat_axes(caches)
    if layout is None:
        layout = cache_row_layout(caches)
    rows = jnp.asarray(rows, dtype=jnp.int32)
    return ops.kv_snapshot_capture(tuple(leaves), rows, layout=layout,
                                   impl=impl)


def cache_write_rows(caches, blob, rows, *, layout=None, impl="pallas"):
    """Batched twin of ``cache_write_row``: scatter staging-blob rows back
    into EVERY leaf at arena rows ``rows`` in a single fused launch.
    Untouched rows pass through."""
    from repro.kernels import ops
    leaves, _axes, treedef = cache_flat_axes(caches)
    if layout is None:
        layout = cache_row_layout(caches)
    rows = jnp.asarray(rows, dtype=jnp.int32)
    new = ops.kv_snapshot_restore(tuple(leaves), blob, rows, layout=layout,
                                  impl=impl)
    return jax.tree.unflatten(treedef, list(new))


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def _train_positions(cfg, batch: int, seq: int):
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos, (3, batch, seq))
    return pos


def _decode_positions(cfg, positions):
    """(B,) host-tracked global positions -> model positions."""
    if cfg.mrope_sections and positions.ndim == 1:
        return jnp.broadcast_to(positions, (3,) + positions.shape)
    return positions


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def forward_train(cfg: ModelConfig, params, batch: dict, *,
                  remat: bool = True):
    """batch: tokens (B,S) [+ vision_embeds (B,N,D) | frames (B,src,D)].
    Returns logits (B,S,Vp)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = batch.get("positions")
    if pos is None:
        pos = _train_positions(cfg, b, s)
    x = transformer.embed_tokens(cfg, params["embed"], tokens,
                                 batch.get("vision_embeds"))
    if cfg.family == "audio":
        enc_out = encdec.run_encoder(cfg, params["encoder"], batch["frames"])
        x, _ = encdec.run_decoder(cfg, params["decoder"], x, mode="train",
                                  positions=pos, enc_out=enc_out, remat=remat)
    else:
        x, _ = transformer.run_decoder(cfg, params["decoder"], x,
                                       mode="train", positions=pos,
                                       remat=remat)
    return transformer.lm_logits(cfg, params["embed"], x)


def prefill(cfg: ModelConfig, params, batch: dict, caches):
    """Fill caches from a prompt; returns (last-token logits (B,Vp), caches).
    All rows prefill from position 0 (scheduler admits fresh partitions)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = batch.get("positions")
    if pos is None:
        pos = _train_positions(cfg, b, s)
    x = transformer.embed_tokens(cfg, params["embed"], tokens,
                                 batch.get("vision_embeds"))
    if cfg.family == "audio":
        enc_out = encdec.run_encoder(cfg, params["encoder"], batch["frames"])
        x, new_caches = encdec.run_decoder(cfg, params["decoder"], x,
                                           mode="prefill", caches=caches,
                                           positions=pos, enc_out=enc_out)
    else:
        x, new_caches = transformer.run_decoder(cfg, params["decoder"], x,
                                                mode="prefill", caches=caches,
                                                positions=pos)
    logits = transformer.lm_logits(cfg, params["embed"], x[:, -1:])
    return logits[:, 0], new_caches


def decode_step(cfg: ModelConfig, params, tokens, positions, caches):
    """One decode step.  tokens (B,1) int32; positions (B,) global position
    of the new token per row (continuous batching: rows are independent).
    Returns (logits (B,Vp), new caches)."""
    pos = _decode_positions(cfg, positions)
    x = transformer.embed_tokens(cfg, params["embed"], tokens)
    if cfg.family == "audio":
        x, new_caches = encdec.run_decoder(cfg, params["decoder"], x,
                                           mode="decode", caches=caches,
                                           positions=pos)
    else:
        x, new_caches = transformer.run_decoder(cfg, params["decoder"], x,
                                                mode="decode", caches=caches,
                                                positions=pos)
    logits = transformer.lm_logits(cfg, params["embed"], x)
    return logits[:, 0], new_caches
