"""Mamba2 / SSD (state-space duality) block.  [arXiv:2405.21060]

Train/prefill use the chunked SSD algorithm: quadratic attention-like math
within chunks of ``ssm_chunk`` tokens, a lax.scan state recurrence across
chunks — O(S * L) compute, O(1)-in-S decode state.  Decode is the plain
diagonal recurrence h = h * exp(dt*A) + dt * (B (x) x).

Projections are kept separate (z/x/B/C/dt) instead of mamba2's fused
``in_proj`` so tensor-parallel sharding stays segment-aligned; FLOPs are
identical.  Per-request decode state = {ssm state + conv tail}: constant
size, the best case for HotMem partitions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (ParamSpec, dense, dense_spec, f32, norm_spec,
                                 rmsnorm)
from repro.sharding import shard


def ssm_spec(cfg):
    d, di = cfg.d_model, cfg.d_inner
    g, ds, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    w = cfg.ssm_conv_width
    return {
        "z": dense_spec(d, di, ("w_embed", "ssm_inner")),
        "x": dense_spec(d, di, ("w_embed", "ssm_inner")),
        "B": dense_spec(d, g * ds, ("w_embed", None)),
        "C": dense_spec(d, g * ds, ("w_embed", None)),
        "dt": dense_spec(d, h, ("w_embed", "ssm_heads")),
        "conv_x": {"w": ParamSpec((w, di), axes=(None, "ssm_inner"),
                                  scale=0.3),
                   "b": ParamSpec((di,), axes=("ssm_inner",), init="zeros")},
        "conv_B": {"w": ParamSpec((w, g * ds), axes=(None, None), scale=0.3),
                   "b": ParamSpec((g * ds,), axes=(None,), init="zeros")},
        "conv_C": {"w": ParamSpec((w, g * ds), axes=(None, None), scale=0.3),
                   "b": ParamSpec((g * ds,), axes=(None,), init="zeros")},
        "A_log": ParamSpec((h,), f32, ("ssm_heads",), init="a_log"),
        "D": ParamSpec((h,), f32, ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((h,), f32, ("ssm_heads",), init="dt_bias"),
        "norm": norm_spec(di, ("ssm_inner",)),
        "out": dense_spec(di, d, ("ssm_inner", "w_embed")),
    }


def _causal_conv(p, x):
    """Depthwise causal conv via shifted adds; x (B,S,C)."""
    w = p["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    s = x.shape[1]
    y = sum(xp[:, i:i + s] * p["w"][i] for i in range(w))
    return jax.nn.silu(y + p["b"])


def _conv_step(p, hist, xt):
    """One-token conv; hist (B, w-1, C), xt (B, C) -> (y, new_hist)."""
    w = p["w"].shape[0]
    full = jnp.concatenate([hist, xt[:, None]], axis=1)     # (B, w, C)
    y = jax.nn.silu(jnp.einsum("bwc,wc->bc", full, p["w"]) + p["b"])
    return y, full[:, -(w - 1):]


def _broadcast_groups(bc, h):
    """(B,...,G,ds) -> (B,...,H,ds)."""
    g = bc.shape[-2]
    return jnp.repeat(bc, h // g, axis=-2)


def ssd_chunked(x, dt, A, B, C, h0):
    """Chunked SSD.  x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,H,N),
    h0 (B,H,P,N) initial state.  Returns (y (B,S,H,P), h_final)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    l = min(256, s)
    assert s % l == 0
    nc = s // l
    xr = x.reshape(b, nc, l, h, p)
    dtr = dt.reshape(b, nc, l, h)
    Br = B.reshape(b, nc, l, h, n)
    Cr = C.reshape(b, nc, l, h, n)

    da = dtr * A                                            # (B,nc,L,H) <= 0
    da_cs = jnp.cumsum(da, axis=2)                          # inclusive cumsum
    da_total = da_cs[:, :, -1]                              # (B,nc,H)

    # intra-chunk (quadratic within chunk)
    seg = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]   # (B,nc,Li,Lj,H)
    causal = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bclhn,bcmhn->bclmh", Cr, Br,
                        preferred_element_type=f32)
    y_intra = jnp.einsum("bclmh,bclmh,bcmh,bcmhp->bclhp",
                         scores, decay, dtr, xr.astype(f32))

    # chunk states + cross-chunk recurrence
    decay_to_end = jnp.exp(da_total[:, :, None, :] - da_cs)    # (B,nc,L,H)
    states = jnp.einsum("bclh,bclh,bclhn,bclhp->bchpn",
                        decay_to_end, dtr, Br, xr.astype(f32))

    def step(h_prev, inp):
        st, tot = inp                                       # (B,H,P,N),(B,H)
        h_new = h_prev * jnp.exp(tot)[:, :, None, None] + st
        return h_new, h_prev

    sc = jnp.moveaxis(states, 1, 0)
    tc = jnp.moveaxis(da_total, 1, 0)
    h_final, h_prevs = jax.lax.scan(step, h0.astype(f32), (sc, tc))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # (B,nc,H,P,N)

    y_inter = jnp.einsum("bclh,bclhn,bchpn->bclhp",
                         jnp.exp(da_cs), Cr, h_prevs)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), h_final


def ssm_block(cfg, p, u, *, mode: str, cache=None):
    """u (B,S,D) -> (y, new_cache)."""
    b, s, _ = u.shape
    h, hd = cfg.ssm_nheads, cfg.ssm_headdim
    g, ds = cfg.ssm_ngroups, cfg.ssm_state
    A = -jnp.exp(p["A_log"].astype(f32))

    z = dense(p["z"], u)
    xr = dense(p["x"], u)
    Br = dense(p["B"], u)
    Cr = dense(p["C"], u)
    dt_raw = dense(p["dt"], u)

    if mode in ("train", "prefill"):
        xc = _causal_conv(p["conv_x"], xr)
        Bc = _causal_conv(p["conv_B"], Br)
        Cc = _causal_conv(p["conv_C"], Cr)
        dt = jax.nn.softplus(dt_raw.astype(f32) + p["dt_bias"])
        xh = shard(xc.reshape(b, s, h, hd), "batch", "seq", "ssm_heads", None)
        Bh = _broadcast_groups(Bc.reshape(b, s, g, ds), h)
        Ch = _broadcast_groups(Cc.reshape(b, s, g, ds), h)
        h0 = jnp.zeros((b, h, hd, ds), f32)
        y, h_final = ssd_chunked(xh, dt, A, Bh, Ch, h0)
        y = y + xh.astype(f32) * p["D"][None, None, :, None]
        new_cache = None
        if mode == "prefill":
            w = cfg.ssm_conv_width
            new_cache = {
                "state": h_final.astype(jnp.bfloat16),
                "conv_x": xr[:, -(w - 1):],
                "conv_B": Br[:, -(w - 1):],
                "conv_C": Cr[:, -(w - 1):],
            }
    else:  # decode: single-token recurrence
        xc, hx = _conv_step(p["conv_x"], cache["conv_x"], xr[:, 0])
        Bc, hB = _conv_step(p["conv_B"], cache["conv_B"], Br[:, 0])
        Cc, hC = _conv_step(p["conv_C"], cache["conv_C"], Cr[:, 0])
        dt = jax.nn.softplus(dt_raw[:, 0].astype(f32) + p["dt_bias"])  # (B,H)
        xh = xc.reshape(b, h, hd)
        Bh = _broadcast_groups(Bc.reshape(b, g, ds), h)
        Ch = _broadcast_groups(Cc.reshape(b, g, ds), h)
        hs = cache["state"].astype(f32)                     # (B,H,P,N)
        hs = hs * jnp.exp(dt * A)[:, :, None, None] \
            + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, xh.astype(f32))
        y = jnp.einsum("bhn,bhpn->bhp", Ch, hs)
        y = y + xh.astype(f32) * p["D"][None, :, None]
        y = y[:, None]                                      # (B,1,H,P)
        new_cache = {"state": hs.astype(jnp.bfloat16),
                     "conv_x": hx, "conv_B": hB, "conv_C": hC}

    y = y.reshape(b, s, cfg.d_inner)
    y = rmsnorm(p["norm"], y.astype(u.dtype) * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out"], y), new_cache


def make_ssm_cache_spec(cfg, batch: int):
    h, hd, ds = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    g, w = cfg.ssm_ngroups, cfg.ssm_conv_width
    from repro.models.layers import bf16
    return {
        "state": ParamSpec((batch, h, hd, ds), bf16,
                           ("batch", "ssm_heads", None, None), init="zeros"),
        "conv_x": ParamSpec((batch, w - 1, cfg.d_inner), bf16,
                            ("batch", None, "ssm_inner"), init="zeros"),
        "conv_B": ParamSpec((batch, w - 1, g * ds), bf16,
                            ("batch", None, None), init="zeros"),
        "conv_C": ParamSpec((batch, w - 1, g * ds), bf16,
                            ("batch", None, None), init="zeros"),
    }
