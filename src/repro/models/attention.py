"""Attention: GQA/MQA, RoPE/M-RoPE, full/sliding-window, softcap, KV caches.

Three modes share one entry point:
  * ``train``   — no cache, causal (optionally windowed) mask.
  * ``prefill`` — as train, but also writes the partition KV cache.
  * ``decode``  — one query token per row against the cache; per-row
                  positions support continuous batching (rows advance
                  independently).  Ring caches (T == window) support
                  unbounded contexts for SWA/local layers.

Long sequences (>= FLASH_SEQ) use a chunked online-softmax path so prefill
at 32k never materializes an S x S score matrix.  The Pallas decode kernels
in ``repro.kernels`` implement the same math for the TPU hot path; this XLA
formulation is what the dry-run lowers (identical FLOPs).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (ParamSpec, apply_rope, dense, dense_spec,
                                 f32, softcap)
from repro.sharding import shard

# chunked online-softmax attention at/above this length.  NOTE (hillclimb
# A3, refuted): lowering this to 4096 for train does NOT bound backward
# memory — under jax.checkpoint the scan backward still saves per-chunk
# probabilities (O(S^2) f32).  A custom-VJP flash kernel is the real lever.
FLASH_SEQ = 8192
Q_CHUNK = 512
KV_CHUNK = 1024
NEG_INF = -2.0 ** 30


def attn_spec(cfg):
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "q": dense_spec(cfg.d_model, hq * dh, ("w_embed", "heads"),
                        bias=cfg.qkv_bias),
        "k": dense_spec(cfg.d_model, hkv * dh, ("w_embed", "kv_heads"),
                        bias=cfg.qkv_bias),
        "v": dense_spec(cfg.d_model, hkv * dh, ("w_embed", "kv_heads"),
                        bias=cfg.qkv_bias),
        "o": dense_spec(hq * dh, cfg.d_model, ("heads", "w_embed")),
    }


def _split_heads(x, n, d):
    return x.reshape(x.shape[:-1] + (n, d))


def _scores(q, k, scale, cap):
    """q (B,S,K,G,D) x k (B,T,K,D) -> (B,K,G,S,T) fp32, softcapped."""
    s = jnp.einsum("bskgd,btkd->bkgst", q, k,
                   preferred_element_type=f32) * scale
    return softcap(s, cap)


def _weighted(v, w):
    """w (B,K,G,S,T) x v (B,T,K,D) -> (B,S,K,G,D)."""
    return jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)


def _plain_attention(q, k, v, qpos, kpos, window, scale, cap):
    s = _scores(q, k, scale, cap)
    mask = kpos[:, None, :] <= qpos[:, :, None]            # causal
    if window:
        mask &= kpos[:, None, :] > qpos[:, :, None] - window
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return _weighted(v, w)


def _chunked_attention(q, k, v, qpos, kpos, window, scale, cap):
    """Online-softmax flash attention in pure jnp (scan over chunks)."""
    b, sq, hk, g, d = q.shape
    t = k.shape[1]
    nq, nk = sq // Q_CHUNK, t // KV_CHUNK
    assert sq % Q_CHUNK == 0 and t % KV_CHUNK == 0, (sq, t)
    qc = jnp.moveaxis(q.reshape(b, nq, Q_CHUNK, hk, g, d), 1, 0)
    qpc = jnp.moveaxis(qpos.reshape(b, nq, Q_CHUNK), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nk, KV_CHUNK, hk, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, KV_CHUNK, hk, d), 1, 0)
    kpc = jnp.moveaxis(kpos.reshape(b, nk, KV_CHUNK), 1, 0)

    def q_step(_, qx):
        qi, qp = qx

        def kv_step(carry, kx):
            m, l, acc = carry
            ki, vi, kp = kx
            s = _scores(qi, ki, scale, cap)                 # (B,K,G,Cq,Ck)
            mask = kp[:, None, :] <= qp[:, :, None]
            if window:
                mask &= kp[:, None, :] > qp[:, :, None] - window
            s = jnp.where(mask[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p, vi.astype(f32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hk, g, Q_CHUNK), NEG_INF, f32)
        l0 = jnp.zeros((b, hk, g, Q_CHUNK), f32)
        a0 = jnp.zeros((b, hk, g, Q_CHUNK, d), f32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kpc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, jnp.moveaxis(out, 3, 1)                # (B,Cq,K,G,D)

    _, out = jax.lax.scan(q_step, None, (qc, qpc))          # (nq,B,Cq,...)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hk, g, d)
    return out.astype(q.dtype)


def _decode_attention(q, cache_k, cache_v, pos, window, scale, cap):
    """q (B,1,K,G,D) vs ring/linear cache (B,T,K,D); pos (B,) is the global
    position of the *current* token (already written into the cache)."""
    b, t = cache_k.shape[:2]
    slots = jnp.arange(t, dtype=jnp.int32)[None, :]          # (B,T)
    # global index held by each slot (writes go to pos % T)
    gidx = pos[:, None] - ((pos[:, None] - slots) % t)
    valid = gidx >= 0
    if window:
        valid &= gidx > pos[:, None] - window
    s = _scores(q, cache_k, scale, cap)                      # (B,K,G,1,T)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return _weighted(cache_v, w)                             # (B,1,K,G,D)


def attention(cfg, p, x, *, positions, mode: str, cache=None,
              window: int = 0):
    """Returns (y, new_cache).  ``positions``: (B,S) [or (3,B,S) M-RoPE] for
    train/prefill; (B,) [or (3,B)] global positions for decode."""
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = hq // hkv
    scale = cfg.query_scale or dh ** -0.5
    cap = cfg.attn_logit_softcap
    b, s = x.shape[:2]

    rope_pos = positions if mode != "decode" else (
        positions[..., None])  # (B,1) / (3,B,1)
    q = _split_heads(dense(p["q"], x), hq, dh)
    k = _split_heads(dense(p["k"], x), hkv, dh)
    v = _split_heads(dense(p["v"], x), hkv, dh)
    q = apply_rope(q, rope_pos, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, rope_pos, cfg.rope_theta, cfg.mrope_sections)
    tok_pos = positions[0] if (cfg.mrope_sections and positions.ndim == 3
                               ) else positions
    if cfg.mrope_sections and mode == "decode" and positions.ndim == 2:
        tok_pos = positions[0]

    if mode in ("train", "prefill"):
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
        qg = q.reshape(b, s, hkv, g, dh)
        from repro.tracemode import is_analysis
        use_flash = s >= FLASH_SEQ and not is_analysis()
        fn = _chunked_attention if use_flash else _plain_attention
        out = fn(qg, k, v, tok_pos, tok_pos, window, scale, cap)
        new_cache = None
        if mode == "prefill":
            t = cache["k"].shape[1]
            if t >= s:                                   # linear fill
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)
            else:                # ring: keep tail; global pos p -> slot p % t
                roll = s % t
                ck = jnp.roll(k[:, -t:], roll, axis=1)
                cv = jnp.roll(v[:, -t:], roll, axis=1)
            new_cache = {
                "k": shard(ck, "batch", "kv_seq", "kv_heads", None),
                "v": shard(cv, "batch", "kv_seq", "kv_heads", None),
            }
    else:
        # Decode: the cache is sequence-sharded over "model" (kv head
        # counts rarely divide 16; at 32k+, T always does).  Heads must be
        # REPLICATED through the attention math — constraining them onto
        # "model" here would conflict with the T-sharding and force the
        # SPMD partitioner into involuntary full rematerialization of the
        # multi-GiB cache.  The o-projection (row-sharded) restores TP via
        # its contraction psum.
        q = shard(q, "batch", None, None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
        t = cache["k"].shape[1]
        idx = tok_pos % t                                # (B,)
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, idx].set(k[:, 0])
        cv = cache["v"].at[rows, idx].set(v[:, 0])
        ck = shard(ck, "batch", "kv_seq", "kv_heads", None)
        cv = shard(cv, "batch", "kv_seq", "kv_heads", None)
        qg = q.reshape(b, s, hkv, g, dh)
        out = _decode_attention(qg, ck, cv, tok_pos, window, scale, cap)
        out = shard(out.reshape(b, s, hq * dh), "batch", "seq", None)
        return dense(p["o"], out), {"k": ck, "v": cv}

    out = out.reshape(b, s, hq * dh)
    out = shard(out, "batch", "seq", "heads")
    return dense(p["o"], out), new_cache


def make_attn_cache_spec(cfg, batch: int, cache_len: int, window: int = 0):
    """ParamSpec tree for one attention block's KV cache."""
    t = min(cache_len, window) if window else cache_len
    from repro.models.layers import bf16
    sp = ParamSpec((batch, t, cfg.num_kv_heads, cfg.head_dim), bf16,
                   ("batch", "kv_seq", "kv_heads", None), init="zeros")
    return {"k": sp, "v": sp}
