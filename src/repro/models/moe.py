"""Mixture-of-Experts FFN: top-k routing with expert capacity.

Tokens are scattered into a dense (E, C, D) dispatch buffer (C = per-expert
capacity), batched-matmul'd through the stacked expert weights, and gathered
back with combine weights.  HLO FLOPs are therefore proportional to
*active* experts (E*C ~ top_k * tokens * capacity_factor), matching the
MoE roofline's 6*N_active*D accounting.

Sharding: expert dim -> "model" (EP, dbrx 16e) or expert d_ff -> "model"
(TP, mixtral 8e, since 8 does not divide the 16-way axis); capacity dim ->
("pod","data") so dispatch buffers stay per-chip-sized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, act_fn, f32
from repro.sharding import shard


def moe_spec(cfg):
    e, d, ff = cfg.num_experts, cfg.d_model, cfg.d_ff
    wa = ("experts", "w_embed", "expert_mlp")
    return {
        "router": ParamSpec((d, e), f32, (None, None)),   # tiny: replicated
        "gate": ParamSpec((e, d, ff), axes=wa),
        "up": ParamSpec((e, d, ff), axes=wa),
        "down": ParamSpec((e, ff, d), axes=("experts", "expert_mlp",
                                            "w_embed")),
    }


def capacity(cfg, n_tokens: int) -> int:
    c = int(n_tokens * cfg.num_experts_per_tok * cfg.moe_capacity_factor
            / cfg.num_experts)
    return max(((c + 7) // 8) * 8, 8)


def moe_block(cfg, p, x):
    """x: (B, S, D) -> (B, S, D).  Dispatches to the shard_map path when a
    mesh context is active (GSPMD cannot partition the capacity scatter —
    it replicates multi-GiB dispatch buffers per chip and floods ICI with
    full-buffer all-reduces; the shard_map path keeps dispatch device-local
    and pays exactly one psum per layer, like a dense TP MLP)."""
    from repro.sharding import current_ctx
    ctx = current_ctx()
    if ctx is not None and "model" in ctx.mesh.shape:
        return _moe_shard_map(cfg, p, x, ctx)
    return _moe_dense(cfg, p, x)


def _moe_shard_map(cfg, p, x, ctx):
    from jax.experimental.shard_map import shard_map
    from repro.sharding import spec_for, shard
    from jax.sharding import PartitionSpec as P

    e, k = cfg.num_experts, cfg.num_experts_per_tok
    ep = cfg.moe_sharding == "ep"
    x = shard(x, "batch", "seq", None)          # tokens: DP only
    xs = spec_for(("batch", "seq", None), x.shape, ctx)
    gs = spec_for(("experts", "w_embed", "expert_mlp"), p["gate"].shape, ctx)
    ds_ = spec_for(("experts", "expert_mlp", "w_embed"), p["down"].shape,
                   ctx)
    model_size = ctx.mesh.shape.get("model", 1)

    def gather_dim(w, spec, dim):
        ax = spec[dim] if dim < len(spec) else None
        if ax is None:
            return w
        return jax.lax.all_gather(w, ax, axis=dim, tiled=True)

    CHUNK = 16384        # bound dispatch-buffer size at long prefills

    def tokens_fn(xt, router, gate, up, down):
        """One chunk of local tokens through the local experts."""
        n, dm = xt.shape
        logits = xt.astype(f32) @ router                       # (n, E)
        top_w, top_e = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        rank = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
        c = max(((int(n * k * cfg.moe_capacity_factor / e) + 7) // 8) * 8, 8)
        keep = rank < c
        rank = jnp.where(keep, rank, 0)
        src = jnp.repeat(jnp.arange(n), k)

        if ep:      # scatter straight into the LOCAL experts' buffer only
            e_loc = gate.shape[0]
            e0 = jax.lax.axis_index("model") * e_loc
            local_expert = (flat_e >= e0) & (flat_e < e0 + e_loc)
            le = jnp.where(local_expert, flat_e - e0, e_loc)   # OOB -> drop
            buf = jnp.zeros((e_loc, c, dm), xt.dtype)
            buf = buf.at[le, rank].add(
                xt[src] * keep[:, None].astype(xt.dtype), mode="drop")
            le = jnp.where(local_expert, flat_e - e0, 0)
        else:       # TP: all experts locally, F sliced
            local_expert = None
            le = flat_e
            buf = jnp.zeros((e, c, dm), xt.dtype)
            buf = buf.at[flat_e, rank].add(
                xt[src] * keep[:, None].astype(xt.dtype), mode="drop")

        h = act_fn(cfg.act, jnp.einsum("ecd,edf->ecf", buf, gate)) \
            * jnp.einsum("ecd,edf->ecf", buf, up)
        out = jnp.einsum("ecf,efd->ecd", h, down)              # partial in D

        gathered = out[le, rank]                               # (n*K, D)
        w = flat_w * keep
        if local_expert is not None:
            w = w * local_expert
        gathered = gathered * w[:, None].astype(xt.dtype)
        return jnp.zeros((n, dm), xt.dtype).at[src].add(gathered)

    def local_fn(xb, router, gate, up, down):
        bl, sl, dm = xb.shape
        n = bl * sl
        xt = xb.reshape(n, dm)
        # FSDP'd weight dims are gathered explicitly (the all-gather XLA
        # would insert outside shard_map, now visible and overlappable)
        gate = gather_dim(gate, gs, 1)
        up = gather_dim(up, gs, 1)
        down = gather_dim(down, ds_, 2)

        if n <= CHUNK:
            y = tokens_fn(xt, router, gate, up, down)
        else:
            nc = -(-n // CHUNK)
            pad = nc * CHUNK - n
            xp = jnp.pad(xt, ((0, pad), (0, 0))).reshape(nc, CHUNK, dm)
            y = jax.lax.map(
                lambda ch: tokens_fn(ch, router, gate, up, down), xp)
            y = y.reshape(nc * CHUNK, dm)[:n]
        y = jax.lax.psum(y, "model")      # combine experts (EP) / F (TP)
        return y.reshape(bl, sl, dm)

    fn = shard_map(local_fn, mesh=ctx.mesh,
                   in_specs=(xs, P(None, None), gs, gs, ds_),
                   out_specs=xs, check_rep=False)
    return fn(x, p["router"], p["gate"], p["up"], p["down"])


def _moe_dense(cfg, p, x):
    """Reference path (no mesh): capacity-based scatter/gather."""
    b, s, d = x.shape
    k, e = cfg.num_experts_per_tok, cfg.num_experts
    n = b * s
    c = capacity(cfg, n)
    xt = x.reshape(n, d)

    logits = (xt.astype(f32) @ p["router"])                     # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                      # (N, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                  # (N*K,)
    flat_w = top_w.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (N*K, E)
    rank = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1    # (N*K,)
    keep = rank < c                                             # drop overflow
    rank = jnp.where(keep, rank, 0)
    src = jnp.repeat(jnp.arange(n), k)                          # token per slot

    buf = jnp.zeros((e, c, d), x.dtype)
    buf = buf.at[flat_e, rank].add(
        xt[src] * keep[:, None].astype(x.dtype), mode="drop")
    buf = shard(buf, "experts", "expert_cap", None)

    h = act_fn(cfg.act, jnp.einsum("ecd,edf->ecf", buf, p["gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["up"])
    h = shard(h, "experts", "expert_cap", "expert_mlp")
    out = jnp.einsum("ecf,efd->ecd", h, p["down"])
    out = shard(out, "experts", "expert_cap", None)

    gathered = out[flat_e, rank]                                # (N*K, D)
    gathered = gathered * (flat_w * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[src].add(gathered)
    return y.reshape(b, s, d)
