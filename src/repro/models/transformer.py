"""Decoder-only transformer assembly: scanned block groups + cache trees.

An architecture is a repeated ``block_pattern`` group (scanned ``n_groups``
times with stacked params — one traced group regardless of depth, keeping
HLO size and compile time flat) plus optional unscanned ``tail_pattern``
blocks.  Heterogeneous patterns (gemma2 local/global pairs, recurrentgemma
rglru/rglru/local triples) scan cleanly because each *position* in the group
has homogeneous params/caches across groups.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (ParamSpec, dense, dense_spec, mlp, mlp_spec,
                                 norm_spec, padded_vocab, rmsnorm, softcap,
                                 stack_specs)
from repro.models.moe import moe_block, moe_spec
from repro.sharding import shard

ATTN_KINDS = ("attn", "local", "global")


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def block_spec(cfg, kind: str):
    d = cfg.d_model
    if kind == "ssm":
        return {"ln": norm_spec(d), "mixer": ssm_mod.ssm_spec(cfg)}
    if kind == "rglru":
        s = {"ln1": norm_spec(d), "rec": rglru_mod.rglru_spec(cfg),
             "ln2": norm_spec(d), "ffn": mlp_spec(cfg)}
        return s
    assert kind in ATTN_KINDS, kind
    ffn = moe_spec(cfg) if cfg.num_experts else mlp_spec(cfg)
    s = {"ln1": norm_spec(d), "attn": attn_mod.attn_spec(cfg),
         "ln2": norm_spec(d), "ffn": ffn}
    if cfg.post_norms:
        s["pn1"] = norm_spec(d)
        s["pn2"] = norm_spec(d)
    return s


def _window_for(cfg, kind: str) -> int:
    if kind == "local":
        return cfg.local_window
    if kind == "global":
        return 0
    return cfg.sliding_window


def block_cache_spec(cfg, kind: str, batch: int, cache_len: int):
    if kind == "ssm":
        return ssm_mod.make_ssm_cache_spec(cfg, batch)
    if kind == "rglru":
        return rglru_mod.make_rglru_cache_spec(cfg, batch)
    return attn_mod.make_attn_cache_spec(cfg, batch, cache_len,
                                         _window_for(cfg, kind))


def decoder_specs(cfg):
    """Param specs for the block stack (no embeddings)."""
    groups = tuple(stack_specs(block_spec(cfg, k), cfg.n_groups)
                   for k in cfg.block_pattern)
    tail = tuple(block_spec(cfg, k) for k in cfg.tail_pattern)
    return {"groups": groups, "tail": tail,
            "final_norm": norm_spec(cfg.d_model)}


def decoder_cache_specs(cfg, batch: int, cache_len: int):
    groups = tuple(
        stack_specs(block_cache_spec(cfg, k, batch, cache_len), cfg.n_groups)
        for k in cfg.block_pattern)
    tail = tuple(block_cache_spec(cfg, k, batch, cache_len)
                 for k in cfg.tail_pattern)
    return {"groups": groups, "tail": tail}


def embed_specs(cfg):
    vp = padded_vocab(cfg)
    out = {"tok": ParamSpec((vp, cfg.d_model), axes=("vocab", "w_embed"),
                            scale=24.0)}
    if not cfg.tie_embeddings:
        out["lm_head"] = dense_spec(cfg.d_model, vp, ("w_embed", "vocab"))
    return out


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def block_forward(cfg, kind: str, p, x, *, mode: str, cache, positions):
    if kind == "ssm":
        h, nc = ssm_mod.ssm_block(cfg, p["mixer"],
                                  rmsnorm(p["ln"], x, cfg.norm_eps),
                                  mode=mode, cache=cache)
        return x + h, nc
    if kind == "rglru":
        h, nc = rglru_mod.rglru_block(cfg, p["rec"],
                                      rmsnorm(p["ln1"], x, cfg.norm_eps),
                                      mode=mode, cache=cache)
        x = x + h
        x = x + mlp(cfg, p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x, nc
    # attention blocks
    h, nc = attn_mod.attention(cfg, p["attn"],
                               rmsnorm(p["ln1"], x, cfg.norm_eps),
                               positions=positions, mode=mode, cache=cache,
                               window=_window_for(cfg, kind))
    if cfg.post_norms:
        h = rmsnorm(p["pn1"], h, cfg.norm_eps)
    x = x + h
    h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
    h2 = moe_block(cfg, p["ffn"], h2) if cfg.num_experts \
        else mlp(cfg, p["ffn"], h2)
    if cfg.post_norms:
        h2 = rmsnorm(p["pn2"], h2, cfg.norm_eps)
    return x + h2, nc


def run_decoder(cfg, params, x, *, mode: str, caches=None, positions=None,
                remat: bool = False):
    """x (B,S,D) -> (y (B,S,D), new_caches).

    With caches, the stacked cache tree rides in the scan CARRY and each
    group updates its slice via dynamic_update — the classic XLA in-place
    while-loop pattern.  (Passing caches as scan xs/ys materializes full
    stacked input AND output buffers as temps: several extra cache-sized
    copies per step, blowing the 16 GiB budget for 70B-class decode.)"""
    pattern = cfg.block_pattern
    has_cache = caches is not None
    from repro.tracemode import scan_unroll

    if not has_cache:
        def group_fn(carry, gp):
            for i, kind in enumerate(pattern):
                carry, _ = block_forward(cfg, kind, gp[i], carry, mode=mode,
                                         cache=None, positions=positions)
            # the scan carry is what remat saves per group; "seq_remat"
            # (None by default) lets wide models store it seq-sharded
            carry = shard(carry, "batch", "seq_remat", "embed")
            return carry, None

        if remat:
            group_fn = jax.checkpoint(
                group_fn, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(group_fn, x, params["groups"],
                            unroll=scan_unroll())
        group_caches = None
    else:
        def group_fn(carry, xs):
            h, gcaches = carry
            gp, gi = xs
            new_gc = []
            for i, kind in enumerate(pattern):
                c = jax.tree.map(
                    lambda l: jax.lax.dynamic_index_in_dim(
                        l, gi, 0, keepdims=False), gcaches[i])
                h, nc = block_forward(cfg, kind, gp[i], h, mode=mode,
                                      cache=c, positions=positions)
                new_gc.append(nc)
            gcaches = tuple(
                jax.tree.map(
                    lambda l, n: jax.lax.dynamic_update_index_in_dim(
                        l, n, gi, 0), gcaches[i], new_gc[i])
                for i in range(len(pattern)))
            h = shard(h, "batch", "seq", "embed")
            return (h, gcaches), None

        gi = jnp.arange(cfg.n_groups, dtype=jnp.int32)
        (x, group_caches), _ = jax.lax.scan(
            group_fn, (x, caches["groups"]), (params["groups"], gi),
            unroll=scan_unroll())

    tail_caches = []
    for i, kind in enumerate(cfg.tail_pattern):
        c = caches["tail"][i] if has_cache else None
        x, nc = block_forward(cfg, kind, params["tail"][i], x, mode=mode,
                              cache=c, positions=positions)
        tail_caches.append(nc)

    new_caches = ({"groups": group_caches, "tail": tuple(tail_caches)}
                  if has_cache else None)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_caches


def embed_tokens(cfg, embed_params, tokens, vision_embeds=None):
    x = jnp.take(embed_params["tok"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
    if vision_embeds is not None:
        n = vision_embeds.shape[1]
        x = jax.lax.dynamic_update_slice_in_dim(
            x, vision_embeds.astype(x.dtype), 0, 1)  # stub patches at front
        del n
    return shard(x, "batch", "seq", "embed")


def lm_logits(cfg, embed_params, x):
    if cfg.tie_embeddings:
        logits = x @ embed_params["tok"].T
    else:
        logits = dense(embed_params["lm_head"], x)
    logits = softcap(logits, cfg.final_logit_softcap)
    return shard(logits, "batch", "seq", "vocab")
