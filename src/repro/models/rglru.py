"""RG-LRU recurrent block (RecurrentGemma / Griffin).  [arXiv:2402.19427]

Block: gated dual-branch — y = GeLU(W_y u); x = RG-LRU(conv4(W_x u));
out = W_o (x * y).  The RG-LRU diagonal recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(L) * r_t),  c = 8,
runs as an associative scan over the sequence (log-depth on TPU) and as a
single step in decode.  Gates use block-diagonal weights (num_heads blocks)
as in Griffin.  Per-request decode state = {h + conv tail}: O(1) in context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, dense, dense_spec, f32
from repro.sharding import shard

LRU_C = 8.0


def rglru_spec(cfg):
    d, w = cfg.d_model, cfg.lru_width
    nb = cfg.num_heads                       # gate blocks
    bs = w // nb
    cw = 4                                   # temporal conv width
    return {
        "y": dense_spec(d, w, ("w_embed", "lru")),
        "x": dense_spec(d, w, ("w_embed", "lru")),
        "conv": {"w": ParamSpec((cw, w), axes=(None, "lru"), scale=0.3),
                 "b": ParamSpec((w,), axes=("lru",), init="zeros")},
        "gate_i": {"w": ParamSpec((nb, bs, bs), axes=("heads", None, None)),
                   "b": ParamSpec((nb, bs), axes=("heads", None),
                                  init="zeros")},
        "gate_r": {"w": ParamSpec((nb, bs, bs), axes=("heads", None, None)),
                   "b": ParamSpec((nb, bs), axes=("heads", None),
                                  init="zeros")},
        "lam": ParamSpec((w,), f32, ("lru",), init="ones"),
        "out": dense_spec(w, d, ("lru", "w_embed")),
    }


def _conv(p, x):
    w = p["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    s = x.shape[1]
    return sum(xp[:, i:i + s] * p["w"][i] for i in range(w)) + p["b"]


def _gates(p, x, nb):
    """Block-diagonal sigmoid gates; x (..., W) -> (r, i) each (..., W)."""
    bs = x.shape[-1] // nb
    xb = x.reshape(x.shape[:-1] + (nb, bs)).astype(f32)
    r = jax.nn.sigmoid(jnp.einsum("...hi,hio->...ho", xb, p["gate_r"]["w"])
                       + p["gate_r"]["b"])
    i = jax.nn.sigmoid(jnp.einsum("...hi,hio->...ho", xb, p["gate_i"]["w"])
                       + p["gate_i"]["b"])
    flat = x.shape[:-1] + (nb * bs,)
    return r.reshape(flat), i.reshape(flat)


def _lru_coeffs(p, x, nb):
    r, i = _gates(p, x, nb)
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r        # (..., W), <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * x.astype(f32))
    return a, b


def rglru_block(cfg, p, u, *, mode: str, cache=None):
    """u (B,S,D) -> (y, new_cache)."""
    b, s, _ = u.shape
    nb = cfg.num_heads
    gate = jax.nn.gelu(dense(p["y"], u))
    x = dense(p["x"], u)

    if mode in ("train", "prefill"):
        xc = _conv(p["conv"], x)
        xc = shard(xc, "batch", "seq", "lru")
        a, bb = _lru_coeffs(p, xc, nb)                     # (B,S,W) f32
        # h_t = a_t h_{t-1} + b_t  via associative scan over S
        aa, hh = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], l[1] * r[0] + r[1]), (a, bb), axis=1)
        h = hh
        new_cache = None
        if mode == "prefill":
            new_cache = {"state": h[:, -1].astype(jnp.bfloat16),
                         "conv": x[:, -(p["conv"]["w"].shape[0] - 1):]}
    else:
        w = p["conv"]["w"].shape[0]
        full = jnp.concatenate([cache["conv"], x[:, 0:1]], axis=1)  # (B,w,W)
        xc = jnp.einsum("bwc,wc->bc", full, p["conv"]["w"]) + p["conv"]["b"]
        a, bb = _lru_coeffs(p, xc, nb)                     # (B,W)
        h1 = a * cache["state"].astype(f32) + bb
        h = h1[:, None]
        new_cache = {"state": h1.astype(jnp.bfloat16), "conv": full[:, 1:]}

    y = h.astype(u.dtype) * gate
    return dense(p["out"], y), new_cache


def make_rglru_cache_spec(cfg, batch: int):
    from repro.models.layers import bf16
    return {
        "state": ParamSpec((batch, cfg.lru_width), bf16, ("batch", "lru"),
                           init="zeros"),
        "conv": ParamSpec((batch, 3, cfg.lru_width), bf16,
                          ("batch", None, "lru"), init="zeros"),
    }
