"""Encoder-decoder backbone (SeamlessM4T-medium).  [arXiv:2308.11596]

The modality frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, src_len, d_model) from ``input_specs()``.
Backbone approximations vs the HF checkpoint: RoPE in place of learned
positions (noted in DESIGN.md).  Decoder = causal self-attention (cached) +
cross-attention (cross-KV cached at prefill) + FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.attention import (_scores, _weighted, _split_heads,
                                    make_attn_cache_spec)
from repro.models.layers import (apply_rope, dense, dense_spec, mlp,
                                 mlp_spec, norm_spec, rmsnorm, stack_specs)
from repro.sharding import shard


def _cross_attn_spec(cfg):
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "q": dense_spec(cfg.d_model, hq * dh, ("w_embed", "heads")),
        "k": dense_spec(cfg.d_model, hkv * dh, ("w_embed", "kv_heads")),
        "v": dense_spec(cfg.d_model, hkv * dh, ("w_embed", "kv_heads")),
        "o": dense_spec(hq * dh, cfg.d_model, ("heads", "w_embed")),
    }


def enc_block_spec(cfg):
    return {"ln1": norm_spec(cfg.d_model), "attn": attn_mod.attn_spec(cfg),
            "ln2": norm_spec(cfg.d_model), "ffn": mlp_spec(cfg)}


def dec_block_spec(cfg):
    return {"ln1": norm_spec(cfg.d_model), "self": attn_mod.attn_spec(cfg),
            "ln2": norm_spec(cfg.d_model), "cross": _cross_attn_spec(cfg),
            "ln3": norm_spec(cfg.d_model), "ffn": mlp_spec(cfg)}


def encoder_specs(cfg):
    return {"src_proj": dense_spec(cfg.d_model, cfg.d_model,
                                   ("w_embed", None)),
            "blocks": stack_specs(enc_block_spec(cfg), cfg.encoder_layers),
            "final_norm": norm_spec(cfg.d_model)}


def decoder_specs(cfg):
    return {"blocks": stack_specs(dec_block_spec(cfg), cfg.num_layers),
            "final_norm": norm_spec(cfg.d_model)}


def dec_cache_specs(cfg, batch: int, cache_len: int):
    self_spec = make_attn_cache_spec(cfg, batch, cache_len)
    cross = make_attn_cache_spec(cfg, batch, cfg.encoder_src_len)
    block = {"self": self_spec, "cross": cross}
    return {"blocks": stack_specs(block, cfg.num_layers)}


# ---------------------------------------------------------------------------


def _bidir_attention(cfg, p, x, positions):
    """Full bidirectional self-attention (encoder)."""
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b, s = x.shape[:2]
    q = apply_rope(_split_heads(dense(p["q"], x), hq, dh), positions,
                   cfg.rope_theta)
    k = apply_rope(_split_heads(dense(p["k"], x), hkv, dh), positions,
                   cfg.rope_theta)
    v = _split_heads(dense(p["v"], x), hkv, dh)
    qg = q.reshape(b, s, hkv, hq // hkv, dh)
    w = jax.nn.softmax(_scores(qg, k, dh ** -0.5, 0.0), axis=-1)
    out = _weighted(v, w).reshape(b, s, hq * dh)
    return dense(p["o"], out)


def run_encoder(cfg, params, frames):
    """frames (B, src, D) stub embeddings -> encoder output (B, src, D)."""
    x = dense(params["src_proj"], frames.astype(jnp.bfloat16))
    x = shard(x, "batch", "src", "embed")
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def block(carry, p):
        h = _bidir_attention(cfg, p["attn"],
                             rmsnorm(p["ln1"], carry, cfg.norm_eps), pos)
        carry = carry + h
        carry = carry + mlp(cfg, p["ffn"],
                            rmsnorm(p["ln2"], carry, cfg.norm_eps))
        return carry, None

    from repro.tracemode import scan_unroll
    x, _ = jax.lax.scan(block, x, params["blocks"], unroll=scan_unroll())
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


def _cross_attention(cfg, p, x, enc_out=None, cache=None):
    """Decoder cross-attention.  At prefill/train ``enc_out`` is given and
    cross-KV is computed (and cached); at decode the cache is used."""
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b, s = x.shape[:2]
    q = _split_heads(dense(p["q"], x), hq, dh)
    if enc_out is not None:
        k = _split_heads(dense(p["k"], enc_out), hkv, dh)
        v = _split_heads(dense(p["v"], enc_out), hkv, dh)
    else:
        k, v = cache["k"], cache["v"]
    qg = q.reshape(b, s, hkv, hq // hkv, dh)
    w = jax.nn.softmax(_scores(qg, k, dh ** -0.5, 0.0), axis=-1)
    out = _weighted(v, w).reshape(b, s, hq * dh)
    new_cache = {"k": k, "v": v} if cache is not None else None
    return dense(p["o"], out), new_cache


def run_decoder(cfg, params, x, *, mode: str, caches=None, positions=None,
                enc_out=None, remat: bool = False):
    """Decoder over token embeddings x (B,S,D)."""
    has_cache = caches is not None

    from repro.tracemode import scan_unroll

    def body(h, p, c):
        hh, self_c = attn_mod.attention(
            cfg, p["self"], rmsnorm(p["ln1"], h, cfg.norm_eps),
            positions=positions, mode=mode,
            cache=c["self"] if has_cache else None)
        h = h + hh
        hh, cross_c = _cross_attention(
            cfg, p["cross"], rmsnorm(p["ln2"], h, cfg.norm_eps),
            enc_out=enc_out, cache=c["cross"] if has_cache else None)
        h = h + hh
        h = h + mlp(cfg, p["ffn"], rmsnorm(p["ln3"], h, cfg.norm_eps))
        nc = {"self": self_c, "cross": cross_c} if has_cache else None
        return h, nc

    if not has_cache:
        def block(carry, p):
            h, _ = body(carry, p, None)
            return h, None

        if remat:
            block = jax.checkpoint(
                block, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(block, x, params["blocks"],
                            unroll=scan_unroll())
        new_caches = None
    else:
        # caches ride in the carry (in-place while pattern; see
        # transformer.run_decoder)
        def block(carry, xs):
            h, bcaches = carry
            p, bi = xs
            c = jax.tree.map(
                lambda l: jax.lax.dynamic_index_in_dim(
                    l, bi, 0, keepdims=False), bcaches)
            h, nc = body(h, p, c)
            bcaches = jax.tree.map(
                lambda l, n: jax.lax.dynamic_update_index_in_dim(
                    l, n, bi, 0), bcaches, nc)
            return (h, bcaches), None

        bi = jnp.arange(cfg.num_layers, dtype=jnp.int32)
        (x, blocks), _ = jax.lax.scan(
            block, (x, caches["blocks"]), (params["blocks"], bi),
            unroll=scan_unroll())
        new_caches = {"blocks": blocks}
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), new_caches
