"""Request model: the serverless-function-invocation analogue.

A request declares its token budget up front (``max_tokens`` — the paper's
user-declared function memory limit); the budget sizes its HotMem partition.
``FunctionProfile`` mirrors the paper's Table 1 workloads (Cnn / Bert / BFS /
HTML): different budgets and compute weights driven by separate traces.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class State(enum.Enum):
    PENDING = "pending"      # in admission waitqueue
    PREFILL = "prefill"
    RUNNING = "running"      # decoding
    DONE = "done"
    KILLED = "killed"        # exceeded declared budget (OOM-kill analogue)


@dataclasses.dataclass
class FunctionProfile:
    """Paper Table 1 analogue: per-function resource declaration."""
    name: str
    prompt_tokens: int
    decode_tokens: int        # typical completion length
    max_tokens: int           # declared budget (partition size driver)
    weight: float = 1.0       # relative invocation rate
    # multi-tenant / SLO-tier metadata (empty = single-tenant default /
    # "standard" tier).  ``slo_tier`` is one of "tight" (latency-critical:
    # spend warm/snapshot capacity here), "standard", "batch" (throughput
    # traffic: routed cold, never spends cached warm state).
    tenant: str = ""
    slo_tier: str = "standard"


# the four paper workloads, scaled to token budgets
PROFILES = {
    "cnn": FunctionProfile("cnn", prompt_tokens=24, decode_tokens=24,
                           max_tokens=64),
    "bert": FunctionProfile("bert", prompt_tokens=48, decode_tokens=40,
                            max_tokens=96),
    "bfs": FunctionProfile("bfs", prompt_tokens=16, decode_tokens=32,
                           max_tokens=64),
    "html": FunctionProfile("html", prompt_tokens=8, decode_tokens=16,
                            max_tokens=32),
}


@dataclasses.dataclass
class Request:
    rid: str
    profile: FunctionProfile
    submit_s: float
    prompt: Optional[list[int]] = None
    state: State = State.PENDING
    partition: Optional[int] = None      # arena row once admitted
    position: int = 0                    # decode cursor (global position)
    target_tokens: int = 0               # when to stop
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    # per-request overrides; empty = inherit from the profile
    tenant: str = ""
    slo_tier: str = ""

    @property
    def latency(self) -> Optional[float]:
        return None if self.done_s is None else self.done_s - self.submit_s


def tenant_of(req: Request) -> str:
    """Effective tenant: request override > profile > '' (single-tenant)."""
    return req.tenant or req.profile.tenant


def slo_tier_of(req: Request) -> str:
    """Effective SLO tier: request override > profile > 'standard'."""
    return req.slo_tier or req.profile.slo_tier or "standard"
