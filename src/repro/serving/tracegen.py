"""Bursty invocation traces (Azure Functions trace shape, synthesized).

The paper drives its evaluation with Azure traces [Shahrad et al. 2020]:
heavy initial bursts that spawn many instances, then an abrupt load drop
that triggers recycling and VM shrinking.  ``bursty_trace`` reproduces that
shape deterministically: Poisson base load overlaid with burst windows of
``burst_x`` higher rate, then a quiet tail.
"""
from __future__ import annotations

import numpy as np


def bursty_trace(duration_s: float, base_rate: float, *, burst_x: float = 8.0,
                 burst_at: tuple[float, ...] = (0.0,), burst_len: float = 5.0,
                 quiet_after: float | None = None, seed: int = 0
                 ) -> list[float]:
    """Arrival times in [0, duration).  Rate = base_rate, x ``burst_x``
    inside burst windows, ~0 after ``quiet_after`` (the drop that triggers
    scale-down in the paper's Fig. 8)."""
    rng = np.random.default_rng(seed)
    out: list[float] = []
    t = 0.0
    while t < duration_s:
        rate = base_rate
        for b in burst_at:
            if b <= t < b + burst_len:
                rate = base_rate * burst_x
        if quiet_after is not None and t >= quiet_after:
            rate = base_rate * 0.02
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        if t < duration_s:
            out.append(t)
    return out


def assign_profiles(arrivals: list[float], profiles: dict, seed: int = 0):
    """Randomly map arrivals to function profiles (weighted)."""
    rng = np.random.default_rng(seed + 1)
    names = list(profiles)
    w = np.array([profiles[n].weight for n in names], float)
    w /= w.sum()
    picks = rng.choice(len(names), size=len(arrivals), p=w)
    return [(t, profiles[names[i]]) for t, i in zip(arrivals, picks)]
