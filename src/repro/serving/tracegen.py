"""Bursty invocation traces (Azure Functions trace shape, synthesized).

The paper drives its evaluation with Azure traces [Shahrad et al. 2020]:
heavy initial bursts that spawn many instances, then an abrupt load drop
that triggers recycling and VM shrinking.  ``bursty_trace`` reproduces that
shape deterministically: Poisson base load overlaid with burst windows of
``burst_x`` higher rate, then a quiet tail.  ``diurnal_trace`` adds the
slow day/night modulation the multi-tenant scenario bank layers tenant
mixes on (one tenant peaking while another idles).

Per-stream seeding: a multi-tenant scenario draws one trace per tenant.
If every stream derived its rng from the same scalar seed, editing one
tenant's parameters would silently reshuffle every OTHER tenant's
arrivals (the draws are coupled through one generator sequence).
``stream_seed`` derives an independent, process-stable child seed from
``(seed, stream_name)`` — ``zlib.crc32``, NOT ``hash()``, which is
salted per process — so each tenant's interleaving is a function of its
own name and parameters only.  ``bursty_trace`` / ``diurnal_trace`` /
``assign_profiles`` take an optional ``stream=`` for exactly this; with
``stream=None`` they reproduce the legacy single-seed draws bit-for-bit.
"""
from __future__ import annotations

import zlib

import numpy as np


def stream_seed(seed: int, stream: str) -> np.random.SeedSequence:
    """Independent child seed for a named trace stream: stable across
    processes and unaffected by any other stream's parameters."""
    return np.random.SeedSequence([seed, zlib.crc32(stream.encode())])


def _stream_rng(seed: int, stream: str | None, legacy_offset: int = 0
                ) -> np.random.Generator:
    """Legacy path (``stream=None``): the original scalar-seed generator,
    bit-identical to the pre-stream behavior.  Named path: independent
    per-stream child."""
    if stream is None:
        return np.random.default_rng(seed + legacy_offset)
    return np.random.default_rng(stream_seed(seed, stream))


def bursty_trace(duration_s: float, base_rate: float, *, burst_x: float = 8.0,
                 burst_at: tuple[float, ...] = (0.0,), burst_len: float = 5.0,
                 quiet_after: float | None = None, seed: int = 0,
                 stream: str | None = None) -> list[float]:
    """Arrival times in [0, duration).  Rate = base_rate, x ``burst_x``
    inside burst windows, ~0 after ``quiet_after`` (the drop that triggers
    scale-down in the paper's Fig. 8)."""
    rng = _stream_rng(seed, stream)
    out: list[float] = []
    t = 0.0
    while t < duration_s:
        rate = base_rate
        for b in burst_at:
            if b <= t < b + burst_len:
                rate = base_rate * burst_x
        if quiet_after is not None and t >= quiet_after:
            rate = base_rate * 0.02
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        if t < duration_s:
            out.append(t)
    return out


def diurnal_trace(duration_s: float, base_rate: float, *,
                  period_s: float = 60.0, depth: float = 0.8,
                  phase: float = 0.0, seed: int = 0,
                  stream: str | None = None) -> list[float]:
    """Sinusoidally modulated Poisson arrivals: rate swings between
    ``base_rate * (1 - depth)`` and ``base_rate * (1 + depth)`` over
    ``period_s`` (the compressed day/night cycle).  Two tenants with
    opposite ``phase`` peak at opposite times — the diurnal-mix scenario's
    load shape, where one tenant's peak leans on the slack the other's
    trough frees up."""
    assert 0.0 <= depth <= 1.0, depth
    rng = _stream_rng(seed, stream)
    out: list[float] = []
    t = 0.0
    peak = base_rate * (1.0 + depth)
    while t < duration_s:
        # thinning: draw at the peak rate, keep with prob rate(t)/peak
        t += float(rng.exponential(1.0 / max(peak, 1e-9)))
        if t >= duration_s:
            break
        rate = base_rate * (1.0 + depth * np.sin(
            2.0 * np.pi * (t / period_s) + phase))
        if rng.uniform() * peak < rate:
            out.append(t)
    return out


def assign_profiles(arrivals: list[float], profiles: dict, seed: int = 0,
                    stream: str | None = None):
    """Randomly map arrivals to function profiles (weighted).  With a
    ``stream`` name the picks come from that stream's independent child
    rng (see module docstring); ``stream=None`` keeps the legacy
    ``seed + 1`` draws bit-identical."""
    rng = _stream_rng(seed, stream, legacy_offset=1)
    names = list(profiles)
    w = np.array([profiles[n].weight for n in names], float)
    w /= w.sum()
    picks = rng.choice(len(names), size=len(arrivals), p=w)
    return [(t, profiles[names[i]]) for t, i in zip(arrivals, picks)]
