"""Elastic serving engine: the FaaS-runtime analogue of paper §4.1.

One engine = one replica (VM).  Requests (function invocations) are admitted
into arena partitions, prefilled (cold start), batch-decoded (continuous
batching), kept warm for ``keep_alive`` (idle container pool), recycled, and
the arena is resized up/down a bucket ladder as demand moves (plug/unplug).

Start paths, fastest first (each leaves its own ``StepEvent``):
  warm_start — a kept-alive container's partition is re-bound by metadata
               adoption (zero data movement, zero wall);
  restore    — the host snapshot pool held the function's prefix KV (a warm
               container expired earlier and its partition was copied out
               instead of discarded); one host->device row write, no model
               compute;
  prefill    — cold start: full prompt forward pass.
When a warm container expires past keep-alive, its partition is offered
to the broker's snapshot pool first (``_offer_snapshot`` — a real device
readout, paid in bytes and wall) and only then released.  Warm-suffix
eviction under host pressure deliberately discards instead: at pressure
time a capture would either divert the open grant's units or be squeezed
right back (see ``_evict_warm_suffix``).

Timebase: a *virtual clock* advanced by the measured wall time of every
device operation (prefill, decode step, migration, zero-fill).  Arrivals are
virtual-time stamped, so trace-driven benchmarks measure real relative costs
(reclaim vs decode interference) without running 300 wall-clock seconds.

Modes (paper Fig. 8/9/10):
  hotmem  — partition arena; shrink is metadata + prefix slice.
  vanilla — same compute, but a physical paged twin of the KV leaves is
            maintained; shrink must first run real migration copies.
  static  — statically over-provisioned (never resizes).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.host import (AlwaysGrantBroker, Grant, MemoryBroker,
                                ReclaimOrder)
from repro.configs.base import ModelConfig
from repro.core.arena import ArenaSpec, ReclaimEvent
from repro.core.elastic import ElasticArena, bucket_ladder, target_bucket
from repro.kernels import kv_snapshot
from repro.models import model as M
from repro.serving.request import Request, State, slo_tier_of

i32 = jnp.int32


# ---------------------------------------------------------------------------
# Snapshot data plane: staged row blobs + content-addressed pagination.
# Pure host-side logic lives at module level so the fast tier can test it
# without booting an engine.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StagedRow:
    """Host-side snapshot payload: ONE contiguous byte buffer (the fused
    capture kernel's single ``device_get``) plus enough metadata to carve
    it back into a batch==1 cache tree of zero-copy views on demand."""
    blob: np.ndarray             # (row_bytes,) uint8
    treedef: Any                 # cache tree structure
    metas: tuple                 # ((row-slice shape, dtype str), ...)

    @property
    def nbytes(self) -> int:
        return int(self.blob.nbytes)

    def tree(self):
        return blob_to_row_tree(self.blob, self.treedef, self.metas)


def blob_to_row_tree(blob_u8: np.ndarray, treedef, metas):
    """Carve a staged row blob into a batch==1 cache tree of zero-copy
    ``np.frombuffer`` views — no bytes move; every leaf aliases the blob."""
    leaves, off = [], 0
    for shape, dtype in metas:
        dt = np.dtype(dtype)
        n = int(np.prod(shape))
        leaves.append(np.frombuffer(blob_u8, dtype=dt, count=n,
                                    offset=off).reshape(shape))
        off += n * dt.itemsize
    assert off == blob_u8.nbytes, (off, blob_u8.nbytes)
    return jax.tree.unflatten(treedef, leaves)


def paginate_blob(blob_u8, units: int, page_bytes: int,
                  n_dev: int = 1) -> list:
    """Split a staged row blob into fixed-size content-addressed pages.

    Each chunk is hashed in place (memoryview slices — the blob is never
    re-materialized as one bytes object) and keyed by content digest with
    the page's unit charge folded in, so one digest always means one
    (content, units) pair — the store asserts that.  Units spread over
    the pages in whole mesh stripes so ANY subset of pages charges
    balanced across devices; short manifests may carry zero-unit tail
    pages.  The digest formula is pinned: the fused blob's byte image
    equals the per-leaf ``tobytes()`` concatenation of the old path, so
    digests (and the dedup baselines keyed on them) are unchanged."""
    mv = memoryview(np.ascontiguousarray(blob_u8)).cast("B")
    chunks = [mv[i:i + page_bytes]
              for i in range(0, len(mv), page_bytes)] or [memoryview(b"")]
    assert units % n_dev == 0, (units, n_dev)
    base, rem = divmod(units // n_dev, len(chunks))
    specs = []
    for i, chunk in enumerate(chunks):
        u = (base + (1 if i < rem else 0)) * n_dev
        digest = "%s-%d" % (hashlib.sha256(chunk).hexdigest()[:16], u)
        specs.append((digest, u, len(chunk), bytes(chunk)))
    return specs


def assemble_pages(specs: list) -> np.ndarray:
    """Concatenate page payloads into ONE contiguous uint8 host buffer:
    each page is wrapped in a zero-copy ``np.frombuffer`` view and copied
    exactly once into its slot — the single host-side copy a paged
    restore pays before its one fused host->device transfer."""
    total = sum(b for _d, _u, b, _p in specs)
    out = np.empty(total, np.uint8)
    off = 0
    for _d, _u, b, p in specs:
        out[off:off + b] = np.frombuffer(p, np.uint8, count=b)
        off += b
    return out


@dataclasses.dataclass
class StepEvent:
    t: float                 # virtual time at start
    kind: str                # decode | prefill | plug | unplug
    wall_s: float
    detail: dict[str, Any] = dataclasses.field(default_factory=dict)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, spec: ArenaSpec, *,
                 mode: str = "hotmem", keep_alive: float = 10.0,
                 headroom: int = 1, seed: int = 0, prewarm: bool = True,
                 broker: Optional[MemoryBroker] = None,
                 replica_id: str = "r0",
                 snapshot_page_bytes: Optional[int] = None,
                 snapshot_impl: Optional[str] = None):
        assert mode in ("hotmem", "vanilla", "static")
        assert snapshot_page_bytes is None or snapshot_page_bytes > 0
        assert snapshot_impl in (None, "pallas", "ref")
        if mode == "vanilla":
            assert cfg.family not in ("ssm", "hybrid"), \
                "paged baseline mirrors token-extensive KV only"
        self.cfg = cfg
        self.params = params
        self.spec = spec
        self.mode = mode
        self.keep_alive = keep_alive
        self.headroom = headroom
        self.ladder = bucket_ladder(spec.n_partitions,
                                    min_units=min(2, spec.n_partitions))
        start = spec.n_partitions if mode == "static" else self.ladder[0]
        # vanilla's model-facing row view stays full-size (compute reads
        # through block tables conceptually); its *physical* pool resizes
        rows = spec.n_partitions if mode in ("static", "vanilla") else start
        self.caches = M.init_caches(cfg, rows, spec.partition_tokens)
        # physical paged twin of token-extensive KV leaves (vanilla only);
        # for hotmem/static the arena is metadata-only and the engine owns
        # the device tree (one copy, donated through the decode step)
        self.pool = self._make_pool(start) if mode == "vanilla" else None
        self.arena = ElasticArena(cfg, spec, mode, caches=self.pool,
                                  seed=seed, grant=self._host_grant,
                                  release=self._host_release)
        if mode != "vanilla":
            # managers sized in partitions; ladder starts small
            self.arena.manager.plugged = start
            import heapq
            self.arena.manager._free = list(range(start))
            heapq.heapify(self.arena.manager._free)
        else:
            bpp = spec.blocks_per_partition
            self.arena.manager.pool_blocks = start * bpp
            self.arena.manager._free = list(range(start * bpp))
            self.arena.manager._rng.shuffle(self.arena.manager._free)

        # host control plane: growth is a *request* to the broker, never a
        # unilateral resize.  Standalone engines get an unmetered broker,
        # so single-replica behavior is byte-identical to pre-broker code.
        # Async pipeline state: reclaim orders this VM owes the host
        # (drained incrementally at tick boundaries) and open grants whose
        # pending fills we claim as victims drain.
        self.replica_id = replica_id
        self._reclaim_orders: deque[ReclaimOrder] = deque()
        self._open_grants: list[Grant] = []
        self.drain_parts_per_tick = 1
        self.broker = broker if broker is not None else AlwaysGrantBroker()
        # sharded hosts: the replica's KV stripes one shard per device of
        # the broker's mesh.  Partitions are the engine's native grow/
        # shrink granule, so each partition must stripe evenly over the
        # mesh — asserted at boot, not discovered mid-reclaim.
        topo = getattr(self.broker, "topology", None)
        self._n_dev = topo.n_devices if topo is not None else 1
        if self._n_dev > 1:
            assert spec.blocks_per_partition % self._n_dev == 0, \
                f"partition of {spec.blocks_per_partition} blocks does " \
                f"not stripe over {self._n_dev} devices"
            # vanilla plugs/unplugs single blocks, which cannot stripe
            assert mode != "vanilla", \
                "vanilla mode is incompatible with a sharded host"
        self.broker.register(
            replica_id, start * spec.blocks_per_partition,
            reclaim=self.reclaim_for_broker, load=self.load, mode=mode,
            order_sink=None if mode == "static" else self._enqueue_order,
            shards=self._n_dev)

        self.now = 0.0
        self.pending: deque[Request] = deque()
        self.active: dict[str, Request] = {}
        self.warm: dict[str, list[tuple[float, str, int]]] = {}
        self.done: list[Request] = []
        self.events: list[StepEvent] = []
        # authoritative start-path counters: which admission path actually
        # ran (the router's route-time picks are predictions, these are
        # outcomes — see Router's accounting note)
        self.cold_starts = 0
        self.warm_starts = 0
        self.restore_starts = 0
        self.remote_restore_starts = 0   # restores that paid an inter-host
        #                                  copy (fleet snapshot migration)
        self._prof_tokens: dict[str, int] = {}   # profile -> prompt tokens
        # content-addressed capture (``snapshot_page_bytes`` set): offered
        # partitions split into fixed-size pages keyed by content hash,
        # and ``_mapped`` remembers which page digests this replica has
        # already materialized (captured or restored) — a later restore
        # maps those copy-on-write instead of re-copying them
        self.snapshot_page_bytes = snapshot_page_bytes
        self._mapped: set[str] = set()
        # fused snapshot data plane: rows move as one staging blob through
        # one kernel launch (see repro.kernels.kv_snapshot).  Like the
        # other ops, the Pallas path runs compiled on TPU only; off-TPU
        # the engine times the pure-jnp ref twin (interpret-mode tracing
        # overhead would drown the wall signal) — bit-identical bytes
        # either way, pinned by tests/test_kernels.py.
        self.snapshot_impl = snapshot_impl or \
            ("pallas" if jax.default_backend() == "tpu" else "ref")
        self._snap_layout = None
        self._snap_warmed: set = set()
        # digest -> (device u8 blob, start, stop): where page bytes are
        # already resident ON DEVICE.  A fully-mapped local CoW restore
        # reassembles its row from these slices — zero h2d payload bytes.
        self._device_pages: dict[str, tuple] = {}
        self._row_req: dict[int, Request] = {}
        self._decode_jit: dict[int, Any] = {}       # rows -> compiled step
        self._prefill_jit: dict[int, Any] = {}      # prompt len -> compiled
        if prewarm and mode == "hotmem":
            # AOT bucket ladder (DESIGN.md §5.3): precompile the decode
            # executable for every arena size so bucket switches are
            # metadata + slice, never a recompile
            for rows_n in self.ladder:
                self._warm_decode(rows_n)

    def _warm_decode(self, rows_n: int) -> None:
        if rows_n in self._decode_jit:
            return
        self._decode_jit[rows_n] = jax.jit(
            lambda p, t, po, c: M.decode_step(self.cfg, p, t, po, c),
            donate_argnums=(3,))
        caches = M.init_caches(self.cfg, rows_n, self.spec.partition_tokens)
        toks = jnp.zeros((rows_n, 1), i32)
        pos = jnp.zeros((rows_n,), i32)
        out, _ = self._decode_jit[rows_n](self.params, toks, pos, caches)
        jax.block_until_ready(out)

    # ------------------------------------------------------------ plumbing
    def _host_grant(self, native: int) -> int:
        """Arena host gate: convert this replica's native units (partitions
        for hotmem, blocks for vanilla) to broker blocks, request a grant,
        and floor the immediate portion back to native granularity.  A sync
        broker may steal inline — that victim-side reclaim wall is charged
        to *our* clock too (we serialized behind it); an async broker
        leaves the deficit pending on the grant instead, and the fills are
        claimed at later ticks while our decode proceeds."""
        bpp = self.spec.blocks_per_partition
        want = native if self.mode == "vanilla" else native * bpp
        g = self.broker.request_grant(self.replica_id, want)
        if g.stall_seconds:
            self.now += g.stall_seconds
            self.events.append(StepEvent(self.now, "stall", g.stall_seconds,
                                         {"units": g.granted}))
        if not g.done:
            self._open_grants.append(g)
        got = g.granted
        if self.mode == "vanilla":
            return got
        rem = got % bpp
        if rem:                           # sub-partition remainder: no use
            self.broker.release_units(self.replica_id, rem)
        return got // bpp

    def _enqueue_order(self, order: ReclaimOrder) -> None:
        """Order sink the broker calls under pressure: queue the shrink,
        to be drained incrementally at our own tick boundaries."""
        self._reclaim_orders.append(order)

    def _host_release(self, native: int) -> None:
        self.broker.release_units(
            self.replica_id,
            native if self.mode == "vanilla" else
            native * self.spec.blocks_per_partition)

    def _make_pool(self, parts: int):
        """Physical paged twin: every token-extensive leaf becomes a flat
        (NB, block_tokens, ...) block pool — one manager block id maps to
        the same token range across all layers, exactly the paper's
        whole-memory-block semantics.  Non-token leaves are skipped."""
        bt = self.spec.block_tokens
        t_part = self.spec.partition_tokens
        pools = []

        def to_pool(x, ax):
            tok_ax = ax + 1
            if x.ndim <= tok_ax or x.shape[tok_ax] != t_part:
                return
            if ax == 1:                       # (G, B, T, ...) -> (B, T, G...)
                x = jnp.moveaxis(x, 0, 2)[ :parts]
            else:
                x = x[:parts]
            nb = parts * (t_part // bt)
            pools.append(x.reshape((nb, bt) + x.shape[2:]))

        M.cache_axis_map(self.caches, to_pool)
        return pools or None

    def _rows(self) -> int:
        return M.cache_num_rows(self.caches)

    def _units(self) -> int:
        return self.arena.units() if self.mode != "vanilla" else \
            self.arena.units() // self.spec.blocks_per_partition

    # ------------------------------------------------------------- submit
    def submit(self, req: Request) -> None:
        self._prof_tokens[req.profile.name] = req.profile.prompt_tokens
        self.pending.append(req)

    # -------------------------------------------------------------- admit
    def _try_admit(self) -> None:
        still = deque()
        while self.pending:
            req = self.pending.popleft()
            if req.submit_s > self.now:
                still.append(req)
                continue
            # batch-tier traffic is deliberately started cold: it must not
            # consume a warm container or a pooled snapshot — both are the
            # tight tier's tail-latency capacity (the slo_tiered policy's
            # engine-side half; "standard" is the default and unchanged)
            batch = slo_tier_of(req) == "batch"
            warm = None if batch else self.warm.get(req.profile.name)
            if warm:
                _, old_rid, row = warm.pop()
                self._start_warm(req, old_rid, row)
                continue
            got = self.arena.admit(req.rid)
            if got is None:
                still.append(req)
                continue
            row = got if self.mode != "vanilla" else self._alloc_row(req)
            if row is None:
                still.append(req)
                continue
            # probe restore feasibility first (no accounting): the pool's
            # hit / miss counters track restore fetches, not cold
            # admissions, and a payload-less entry must not be
            # MRU-refreshed by a lookup it can never serve
            snap = self.broker.snapshot_lookup(req.profile.name) \
                if self.mode == "hotmem" and not batch \
                and self.broker.snapshot_restorable(req.profile.name) \
                else None
            if snap is not None:
                self._start_restore(req, row, snap)
            else:
                self._start_cold(req, row)
        self.pending = still

    def _alloc_row(self, req) -> Optional[int]:
        used = set(self._row_req)
        for entries in self.warm.values():          # warm rows stay reserved
            used.update(row for _, _, row in entries)
        for r in range(self._rows()):
            if r not in used:
                return r
        return None

    def _activate(self, req: Request, row: int) -> None:
        """Shared tail of every start path (cold / warm / restore): the
        prompt KV is resident in ``row``, bind the request and enter the
        decode loop."""
        prof = req.profile
        self.arena.on_tokens(req.rid, prof.prompt_tokens)
        req.position = prof.prompt_tokens
        req.target_tokens = prof.prompt_tokens + prof.decode_tokens
        req.state = State.RUNNING
        self._row_req[row] = req
        self.active[req.rid] = req

    def _start_cold(self, req: Request, row: int) -> None:
        req.partition = row
        req.admitted_s = self.now
        req.state = State.PREFILL
        prof = req.profile
        prompt = np.full((1, prof.prompt_tokens),
                         hash(prof.name) % 97 + 1, np.int32)
        n = prof.prompt_tokens
        if n not in self._prefill_jit:
            def _pf(params, toks, row_caches):
                return M.prefill(self.cfg, params, {"tokens": toks},
                                 row_caches)[1]
            self._prefill_jit[n] = jax.jit(_pf, donate_argnums=(2,))
        t0 = time.perf_counter()
        row_caches = M.init_caches(self.cfg, 1, self.spec.partition_tokens)
        row_caches = self._prefill_jit[n](self.params, jnp.asarray(prompt),
                                          row_caches)
        self.caches = M.cache_write_row(self.caches, row_caches, row)
        jax.block_until_ready(jax.tree.leaves(self.caches)[0])
        wall = time.perf_counter() - t0
        self.now += wall
        self.events.append(StepEvent(self.now, "prefill", wall,
                                     {"rid": req.rid}))
        self._activate(req, row)
        self.cold_starts += 1

    def _start_warm(self, req: Request, old_rid: str, row: int) -> None:
        """Warm start: prompt KV still resident in the partition — skip
        prefill entirely (the paper's warm-container fast path).  The
        partition is re-bound by metadata adoption, zero data movement."""
        req.partition = row
        req.admitted_s = self.now
        self.arena.manager.adopt(old_rid, req.rid)
        self._activate(req, row)
        self.warm_starts += 1
        self.events.append(StepEvent(self.now, "warm_start", 0.0,
                                     {"rid": req.rid, "row": row}))

    def _start_restore(self, req: Request, row: int, snap) -> None:
        """Snapshot restore: the function's prefix KV was persisted to the
        host pool when its last warm container was recycled; copy it back
        into the freshly admitted partition.  No prefill forward pass —
        one host->device row write — so it is far cheaper than a cold
        start but, unlike warm adoption, pays real copy bytes.

        Source tagging: an entry the fleet migrated from another host
        still owes its modeled inter-host transfer wall
        (``Snapshot.copy_seconds``); the FIRST restore claims it — the
        event is tagged ``source="remote"`` with the origin host and the
        copy charge, and lands between a local restore and a cold
        prefill.  The entry is local thereafter (later restores tag
        ``source="local"``).

        Content-addressed entries restore COPY-ON-WRITE: pages whose
        digest this replica already materialized (an earlier capture or
        restore) are remapped, not re-copied — the charged wall scales by
        the fraction of pages actually new here, and the event reports
        ``pages_total`` / ``pages_shared``.  When EVERY page of a local
        entry is still resident on device (``_device_pages``), the row is
        reassembled from those mapped slices and scattered in place: the
        payload never leaves the device (zero host->device bytes)."""
        req.partition = row
        req.admitted_s = self.now
        req.state = State.PREFILL
        copy_s = snap.claim_copy() if hasattr(snap, "claim_copy") else 0.0
        specs = self.broker.snapshot_page_specs(snap.key) \
            if getattr(snap, "pages", None) is not None else None
        staged = isinstance(snap.payload, StagedRow)
        layout = remap = None
        if specs is not None or staged:
            layout = self._snapshot_layout()
            self._warm_snapshot_op("restore")
            remap = specs is not None and copy_s == 0.0 and \
                all(d in self._device_pages for d, _u, _b, _p in specs)
        t0 = time.perf_counter()
        if specs is None and not staged:
            # legacy opaque tree payload: per-leaf transfer + row write
            row_caches = jax.tree.map(jnp.asarray, snap.payload)
            self.caches = M.cache_write_row(self.caches, row_caches, row)
        else:
            if remap:
                # fully-mapped local CoW restore: concatenate the mapped
                # on-device byte slices back into a staging blob — no
                # payload byte crosses the host/device boundary
                parts = [dev[s:e] for dev, s, e in
                         (self._device_pages[d] for d, _u, _b, _p in specs)]
                dev_u8 = parts[0] if len(parts) == 1 else \
                    jnp.concatenate(parts)
                dev_blob = jax.lax.bitcast_convert_type(
                    dev_u8.reshape(1, layout.total_elems, layout.itemsize),
                    jnp.dtype(layout.dtype))
                kv_snapshot.note_remap()
            else:
                blob_u8 = self._reassemble(snap.payload, specs) \
                    if specs is not None else snap.payload.blob
                host_blob = blob_u8.view(np.dtype(layout.dtype)).reshape(
                    1, layout.total_elems)
                dev_blob = jnp.asarray(host_blob)   # ONE fused h2d copy
                kv_snapshot.note_h2d(host_blob.nbytes)
            self.caches = M.cache_write_rows(
                self.caches, dev_blob, jnp.asarray([row], i32),
                layout=layout, impl=self.snapshot_impl)
            kv_snapshot.note_launch("restore")
        jax.block_until_ready(jax.tree.leaves(self.caches)[0])
        wall = time.perf_counter() - t0
        detail = {"rid": req.rid, "key": snap.key, "bytes": snap.nbytes,
                  "row": row, "source": "remote" if copy_s else "local"}
        if specs is not None:
            total = len(specs)
            shared = sum(1 for d, _u, _b, _p in specs if d in self._mapped)
            # CoW: only the new pages pay the host->device copy; shared
            # pages are a mapping (the measured wall is the full row
            # write, so scale it by the new-page fraction)
            wall *= (total - shared) / total if total else 1.0
            self._mapped.update(d for d, _u, _b, _p in specs)
            self._index_device_pages(dev_blob, specs)
            detail["pages_total"] = total
            detail["pages_shared"] = shared
        wall += copy_s
        self.now += wall
        if copy_s:
            detail["origin"] = snap.origin_host
            detail["copy_s"] = copy_s
            self.remote_restore_starts += 1
        self.events.append(StepEvent(self.now, "restore", wall, detail))
        self._activate(req, row)
        self.restore_starts += 1

    # -------------------------------------------------------------- decode
    def _decode(self) -> None:
        rows = self._rows()
        toks = np.zeros((rows, 1), np.int32)
        pos = np.zeros((rows,), np.int32)
        for row, req in self._row_req.items():
            assert row < rows, \
                f"active request {req.rid} bound to row {row} but the " \
                f"arena holds only {rows} rows — a shrink dropped a live " \
                f"row (free-suffix invariant violated)"
            pos[row] = req.position
        if rows not in self._decode_jit:
            self._warm_decode(rows)
        t0 = time.perf_counter()
        logits, self.caches = self._decode_jit[rows](
            self.params, jnp.asarray(toks), jnp.asarray(pos), self.caches)
        jax.block_until_ready(logits)
        wall = time.perf_counter() - t0
        self.now += wall
        self.events.append(StepEvent(self.now, "decode", wall,
                                     {"batch": len(self._row_req)}))
        finished = []
        for row, req in list(self._row_req.items()):
            req.position += 1
            if req.first_token_s is None:
                req.first_token_s = self.now
            if not self.arena.on_tokens(req.rid, 1):
                req.state = State.KILLED
                finished.append((row, req))
                continue
            if req.position >= req.target_tokens:
                req.state = State.DONE
                finished.append((row, req))
        for row, req in finished:
            req.done_s = self.now
            self.done.append(req)
            del self.active[req.rid]
            del self._row_req[row]
            if req.state is State.DONE:
                # to warm pool: the partition STAYS BOUND (idle container)
                # until keep-alive expiry recycles it
                self.warm.setdefault(req.profile.name, []).append(
                    (self.now, req.rid, row))
            # KILLED was already force-released by the manager

    # ------------------------------------------------------------- elastic
    def _snapshot_layout(self):
        """Static blob layout of one arena row (row-slice shapes do not
        depend on the arena's row count, so one layout survives every
        bucket switch)."""
        if self._snap_layout is None:
            self._snap_layout = M.cache_row_layout(self.caches)
        return self._snap_layout

    def _warm_snapshot_op(self, kind: str) -> None:
        """Dummy-execute the fused snapshot op for the CURRENT arena shape
        outside any timed region, so the first timed capture / restore
        measures data movement, not a jit compile (the snapshot twin of
        ``_warm_decode``'s AOT discipline).  The restore dummy's output is
        discarded — the op does not donate, so ``self.caches`` is
        untouched."""
        key = (kind, self._rows(), self.snapshot_impl)
        if key in self._snap_warmed:
            return
        layout = self._snapshot_layout()
        rows = jnp.zeros((1,), i32)
        if kind == "capture":
            out = M.cache_read_rows(self.caches, rows, layout=layout,
                                    impl=self.snapshot_impl)
        else:
            blob = jnp.zeros((1, layout.total_elems), layout.dtype)
            out = M.cache_write_rows(self.caches, blob, rows, layout=layout,
                                     impl=self.snapshot_impl)
        jax.block_until_ready(out)
        self._snap_warmed.add(key)

    def _index_device_pages(self, dev_blob, specs: list) -> None:
        """Remember where each page's bytes live ON DEVICE (byte slices of
        the staged blob): a later fully-mapped local CoW restore
        reassembles its row from these slices and never pays a
        host->device payload transfer."""
        dev_u8 = jax.lax.bitcast_convert_type(
            dev_blob, jnp.uint8).reshape(-1)
        off = 0
        for d, _u, b, _p in specs:
            self._device_pages[d] = (dev_u8, off, off + b)
            off += b

    def _offer_snapshot(self, prof_name: str, rid: str, row: int) -> bool:
        """Persist an about-to-be-recycled warm partition to the host
        snapshot pool instead of discarding its prefix KV.  The readout is
        ONE fused gather launch (every leaf's row slice lands in a single
        contiguous staging blob, ``kv_snapshot``) plus ONE device->host
        copy of that blob, charged to this replica's clock — paid only
        when the broker has room (brokers without a pool decline for
        free, keeping the discard path byte-identical to pre-snapshot
        behavior).  ``nbytes`` and pagination both read the same staged
        blob: the old path's double byte-materialization (tree traversal
        for nbytes, then per-leaf ``tobytes()`` again) is gone.

        With ``snapshot_page_bytes`` set the blob is split into
        content-addressed pages (``_paginate``) before the put, so the
        pool charges only pages its store does not already hold.  The
        room probe stays the conservative all-pages-new check — it runs
        BEFORE the device readout, when the page digests do not exist
        yet, so it must not depend on them."""
        if self.mode != "hotmem":
            return False            # prefix-KV rows are a hotmem concept
        units = self.spec.blocks_per_partition
        if not self.broker.snapshot_room(prof_name, units):
            return False
        layout = self._snapshot_layout()
        self._warm_snapshot_op("capture")
        t0 = time.perf_counter()
        dev_blob = M.cache_read_rows(self.caches, jnp.asarray([row], i32),
                                     layout=layout, impl=self.snapshot_impl)
        host = np.asarray(jax.device_get(dev_blob))
        wall = time.perf_counter() - t0
        kv_snapshot.note_launch("capture")
        kv_snapshot.note_d2h(host.nbytes)
        blob_u8 = host.view(np.uint8).reshape(-1)    # zero-copy byte image
        nbytes = blob_u8.nbytes                      # == sum of leaf nbytes
        treedef = jax.tree.structure(self.caches)
        metas = tuple((s.block_shape, layout.dtype) for s in layout.slots)
        pages = None
        if self.snapshot_page_bytes is not None:
            payload: Any = ("paged", treedef, metas)
            pages = self._paginate(blob_u8, units)
        else:
            payload = StagedRow(blob=blob_u8, treedef=treedef, metas=metas)
        ok = self.broker.snapshot_put(
            prof_name, units=units, payload=payload,
            tokens=self._prof_tokens.get(prof_name, 0), nbytes=nbytes,
            replica_id=self.replica_id, pages=pages)
        if ok:
            if pages is not None:
                self._mapped.update(d for d, _u, _b, _p in pages)
                self._index_device_pages(dev_blob, pages)
            self.now += wall
            self.events.append(StepEvent(self.now, "snapshot", wall,
                                         {"key": prof_name, "rid": rid,
                                          "bytes": nbytes, "row": row}))
        return ok

    def _paginate(self, blob_u8: np.ndarray, units: int) -> list:
        """Content-addressed pagination of the staged row blob (module-
        level ``paginate_blob`` does the work — pure host logic, fast-tier
        testable).  Digests are pinned across the kernel migration: the
        fused blob's byte image equals the per-leaf era's ``tobytes()``
        concatenation."""
        return paginate_blob(blob_u8, units, self.snapshot_page_bytes,
                             self._n_dev)

    def _reassemble(self, payload, specs: list) -> np.ndarray:
        """Rebuild the staged row blob from a paged entry: ONE contiguous
        host buffer assembled from zero-copy page views
        (``assemble_pages``).  Carving back into leaves happens on device
        in the single fused scatter-restore launch — not per leaf, and
        not on the host."""
        kind, _treedef, metas = payload
        assert kind == "paged", kind
        blob = assemble_pages(specs)
        want = sum(int(np.prod(s)) * np.dtype(d).itemsize for s, d in metas)
        assert blob.nbytes == want, (blob.nbytes, want)
        return blob

    def _recycle_idle(self) -> None:
        """Recycle idle containers past keep-alive: release their
        partitions/blocks (this is what makes memory reclaimable).  Each
        expiring container's partition is first offered to the host
        snapshot pool (warm-restart state outliving the container)."""
        for prof, entries in list(self.warm.items()):
            fresh = [e for e in entries
                     if self.now - e[0] < self.keep_alive]
            expired = [e for e in entries
                       if self.now - e[0] >= self.keep_alive]
            if expired and not self._reclaim_orders \
                    and not self.broker.snapshot_restorable(prof):
                # capture at most ONE expiring container per profile (the
                # pool keys by profile — same-key replacement would throw
                # away all but the last readout anyway), skip entirely
                # when the pool already holds a restorable copy (per-
                # profile KV is deterministic, so a re-capture would
                # same-key-replace byte-identical content at the cost of
                # a full device readout), and never mid-order-drain: the
                # readout wall would lengthen the very drain the
                # requester is waiting on, and the next pressured grant
                # would squeeze the snapshot right back
                t, rid, row = max(expired)       # newest expiring entry
                self._offer_snapshot(prof, rid, row)
            for (_, rid, _row) in expired:
                self.arena.finish(rid)
            self.warm[prof] = fresh

    def _resize(self) -> None:
        if self.mode == "static":
            return
        demand = len(self.active) + sum(map(len, self.warm.values())) \
            + len(self.pending) + self.headroom
        tgt = target_bucket(self.ladder, max(demand, self.ladder[0]))
        cur = self._units()
        if tgt > cur:
            if self._reclaim_orders:
                # the host ordered this VM to shrink; plugging now would
                # ping-pong the same units back and forth between replicas
                return
            # growth is a plug *request* through the arena's host gate: the
            # broker may grant less than asked (and may first steal from an
            # idler replica to cover it), so size the row sync to what the
            # arena actually got.  Units already in flight on open grants
            # (pending on victims' orders, or escrowed awaiting our claim)
            # must not be re-requested.
            # grants account in broker blocks; tgt/cur are partitions
            owed = sum(g.pending + g.available for g in self._open_grants) \
                // self.spec.blocks_per_partition
            k = tgt - cur - owed
            if k <= 0:
                return
            units = k if self.mode != "vanilla" else \
                k * self.spec.blocks_per_partition
            self._grow_and_sync(units, via_gate=True)
        elif tgt < cur:
            k = cur - tgt
            if self.mode == "hotmem" and \
                    not self.arena.manager.shrink_plan(k):
                return                       # nothing reclaimable yet
            units = k if self.mode != "vanilla" else \
                k * self.spec.blocks_per_partition
            self._unplug_now(units)

    def _grow_and_sync(self, native: int, *, via_gate: bool,
                       detail: Optional[dict] = None) -> int:
        """Grow the arena (through the host gate, or absorbing an
        already-claimed grant fill) + row sync + virtual-clock charge +
        event log — the one plug protocol both growth paths share (the
        bit-identical-trace regression depends on it staying identical).
        Returns native units actually added."""
        before = self.arena.units()
        # absorb path: the claimed fill is a whole stripe (claim_grant only
        # releases coherent units); hotmem's native unit is a partition,
        # which stripes wholly, so only vanilla's block granules need the
        # stripe check — and vanilla is asserted off for sharded hosts.
        wall = self.arena.plug(native) if via_gate \
            else self.arena.absorb(
                native, shards=self._n_dev if self.mode == "vanilla" else 1)
        added = self.arena.units() - before
        if added:
            t0 = time.perf_counter()
            self._sync_rows(self._units())
            jax.block_until_ready(jax.tree.leaves(self.caches)[0])
            wall += time.perf_counter() - t0
            self.now += wall
            self.events.append(StepEvent(self.now, "plug", wall,
                                         {"units": added, **(detail or {})}))
        return added

    def _unplug_now(self, units: int, *, stolen: bool = False
                    ) -> ReclaimEvent:
        """Unplug + row sync + virtual-clock charge + event log — shared by
        self-initiated shrink and broker-initiated steals (which do their
        own host accounting, hence ``notify_host=False``)."""
        ev = self.arena.unplug(units, notify_host=not stolen)
        t0 = time.perf_counter()
        self._sync_rows(self._units())
        jax.block_until_ready(jax.tree.leaves(self.caches)[0])
        ev.wall_seconds += time.perf_counter() - t0
        self.now += ev.wall_seconds
        detail = {"reclaimed_bytes": ev.reclaimed_bytes,
                  "migrated_bytes": ev.migrated_bytes}
        if stolen:
            detail["stolen"] = True
        self.events.append(StepEvent(self.now, "unplug", ev.wall_seconds,
                                     detail))
        return ev

    def _sync_rows(self, parts: int) -> None:
        """Match the model-facing row cache to the arena partition count."""
        if self.mode == "vanilla":
            return
        rows = self._rows()
        if parts == rows:
            return
        if parts > rows:
            self.caches = M.cache_grow_rows(self.caches, parts)
        else:
            self.caches = M.cache_slice_rows(self.caches, parts)

    # ------------------------------------------------------- broker victim
    def load(self) -> int:
        """In-flight + queued invocations (the broker's idleness signal)."""
        return len(self.active) + len(self.pending)

    def _free_units(self) -> int:
        if self.mode == "vanilla":
            return self.arena.manager.free_blocks \
                // self.spec.blocks_per_partition
        return self.arena.manager.free_partitions

    def _evict_warm_suffix(self, k_parts: int) -> None:
        """HotMem shrink drops only a *free suffix* of the arena: extend
        that suffix by recycling warm (idle) containers sitting on its
        high rows, stopping at the first active row — killing anything
        below it cannot help and would waste warm-start state."""
        mgr = self.arena.manager
        warm_rows = {row: (t, prof, rid)
                     for prof, es in self.warm.items()
                     for (t, rid, row) in es}
        free = set(mgr._free)
        need, p = k_parts, mgr.plugged - 1
        while p >= 0 and need > 0:
            if p in free:
                need -= 1
            elif p in warm_rows:
                t, prof, rid = warm_rows[p]
                # deliberately NO snapshot capture here: warm-suffix
                # eviction only ever runs under host pressure (sync
                # inline steal or async order drain), where a capture
                # would either divert the open grant's own units (sync —
                # the broker fences the pool via _inline_reclaim) or
                # lengthen the drain the requester is waiting on and be
                # squeezed right back by the next pressured grant (pure
                # churn).  Capture rides the keep-alive expiry path
                # (_recycle_idle), which runs outside pressure.
                self.arena.finish(rid)
                self.warm[prof].remove((t, rid, p))
                need -= 1
            else:
                break                      # active row blocks the suffix
            p -= 1

    # -------------------------------------------------- async host pipeline
    def host_work(self) -> bool:
        """Open async-pipeline work: reclaim orders to drain (as victim) or
        grant fills to claim (as requester).  ``ClusterSim`` keeps ticking
        a replica while this is true so the pipeline always advances."""
        return bool(self._reclaim_orders) or bool(self._open_grants)

    def _service_reclaim_orders(self) -> None:
        """Drain the pending-unplug queue incrementally: at most
        ``drain_parts_per_tick`` partitions per tick, fencing high rows via
        ``_evict_warm_suffix`` before each partial unplug — so the victim's
        reclaim overlaps the requester's decode instead of stalling it
        (the async pipeline's victim side)."""
        q = self._reclaim_orders
        while q and not q[0].open:
            q.popleft()                  # filled naturally or canceled
        if not q:
            return
        order = q[0]
        chunk = min(self.drain_parts_per_tick
                    * self.spec.blocks_per_partition, order.remaining)
        freed, ev = self.reclaim_for_broker(chunk)
        if freed:
            shards = getattr(order, "shards", 1)
            if shards > 1:
                # sharded host: each freed partition stripes one slab per
                # device, so the fill lands shard-by-shard (the broker only
                # unfences the requester once every shard of the stripe is
                # home — coherent_filled, not filled).
                per = freed // shards
                accepted = sum(
                    self.broker.fulfill_order(order.order_id, per,
                                              ev if d == 0 else None,
                                              shard=d)
                    for d in range(shards))
            else:
                accepted = self.broker.fulfill_order(order.order_id,
                                                     freed, ev)
            if freed > accepted:         # rounding excess: normal release
                self.broker.release_units(self.replica_id, freed - accepted)
            if not order.open:
                q.popleft()
        elif not self.active and not self.pending \
                and not any(self.warm.values()):
            # fully drained VM with nothing left to give: abandon the rest
            # (a victim that finished naturally already filled the order
            # through release routing — this cancel is the leftover)
            self.broker.cancel_order(order.order_id)
            q.popleft()

    def _claim_grants(self, abandon: bool = False) -> None:
        """Requester side of the async pipeline: absorb units that reclaim
        orders drained into our open grants since the last tick (grant
        completion at our own tick boundary, where row growth is legal).
        With ``abandon`` (the engine is fully idle: its demand vanished),
        pending remainders are canceled so victims stop draining for us
        and a standalone ``run`` can terminate."""
        if not self._open_grants:
            return
        bpp = self.spec.blocks_per_partition
        for g in list(self._open_grants):
            got = self.broker.claim_grant(g)
            if got:
                if self.mode != "vanilla" and got % bpp:
                    self.broker.release_units(self.replica_id, got % bpp)
                native = got if self.mode == "vanilla" else got // bpp
                self._grow_and_sync(native, via_gate=False,
                                    detail={"async_fill": True})
            if abandon and not g.done:
                self.broker.abandon_grant(g)
            if g.done and g.available == 0 \
                    and getattr(g, "incoherent", 0) == 0:
                self._open_grants.remove(g)

    def reclaim_for_broker(self, k_blocks: int
                           ) -> tuple[int, Optional[ReclaimEvent]]:
        """Victim side of a host steal: the broker (hypervisor) needs
        ``k_blocks`` back.  Recycle idle warm containers (hotmem: the ones
        blocking the free suffix; vanilla: oldest-first until enough blocks
        are free), then unplug — charging this replica's clock with the
        reclaim stall (hotmem: metadata-only; vanilla: migration copies).
        Returns (blocks actually freed, event)."""
        if self.mode == "static":
            return 0, None
        bpp = self.spec.blocks_per_partition
        k_parts = -(-k_blocks // bpp)
        if self.mode == "hotmem":
            self._evict_warm_suffix(k_parts)
            if not self.arena.manager.shrink_plan(k_parts):
                return 0, None
            units = k_parts
        else:
            entries = sorted((t, prof, rid, row)
                             for prof, es in self.warm.items()
                             for (t, rid, row) in es)
            for t, prof, rid, row in entries:
                if self._free_units() >= k_parts:
                    break
                self.arena.finish(rid)
                self.warm[prof].remove((t, rid, row))
            units = k_parts * bpp
            if self.arena.manager.shrink_plan(units)[0] == 0:
                return 0, None        # nothing reclaimable: skip the
                #                       zero-yield migration pass entirely
        ev = self._unplug_now(units, stolen=True)
        return (ev.reclaimed_units *
                (1 if self.mode == "vanilla" else bpp)), ev

    # ----------------------------------------------------------------- run
    def _tick(self, todo: deque) -> None:
        """One scheduler iteration: submit due arrivals, admit, decode (or
        let time pass), recycle idle containers, resize.  ``run`` loops
        this for a standalone replica; ``ClusterSim`` interleaves ticks
        across replicas in virtual-time order."""
        while todo and todo[0].submit_s <= self.now:
            self.submit(todo.popleft())
        # async host pipeline first: claim grant fills (rows grow before
        # admission) and drain one chunk of any open reclaim order — both
        # at this tick boundary, never inside another replica's request.
        # A fully idle engine abandons pending grants: its demand is gone.
        self._claim_grants(abandon=not todo and not self.active
                           and not self.pending
                           and not any(self.warm.values()))
        self._service_reclaim_orders()
        if not self.active and not self.pending and todo:
            self.now = max(self.now, todo[0].submit_s)
            return
        self._try_admit()
        if self._row_req:
            self._decode()
        elif self.pending:
            # stuck in waitqueue: let time pass so warm rows expire /
            # the next resize can plug (regardless of future arrivals)
            self.now += 0.01
        elif not todo and not self.pending and not self.active:
            # drain: idle containers age out, triggering final unplugs
            # (the paper's post-burst scale-down, Fig. 8)
            self.now += self.keep_alive / 8
        self._recycle_idle()
        self._resize()

    def run(self, requests: list[Request], max_virtual_s: float = 1e9):
        todo = deque(sorted(requests, key=lambda r: r.submit_s))
        while (todo or self.pending or self.active
               or any(self.warm.values()) or self.host_work()) \
                and self.now < max_virtual_s:
            self._tick(todo)
        return self.metrics()

    def metrics(self) -> dict[str, Any]:
        lat = [r.latency for r in self.done
               if r.latency is not None and r.state is State.DONE]
        reclaims = self.arena.manager.reclaim_events
        return {
            "completed": sum(r.state is State.DONE for r in self.done),
            "killed": sum(r.state is State.KILLED for r in self.done),
            "latency_p50": float(np.percentile(lat, 50)) if lat else None,
            "latency_p99": float(np.percentile(lat, 99)) if lat else None,
            "reclaim_events": len(reclaims),
            "reclaimed_bytes": sum(e.reclaimed_bytes for e in reclaims),
            "migrated_bytes": sum(e.migrated_bytes for e in reclaims),
            "reclaim_wall_s": sum(e.wall_seconds for e in reclaims),
            "decode_steps": sum(1 for e in self.events
                                if e.kind == "decode"),
            "cold_starts": self.cold_starts,
            "warm_starts": self.warm_starts,
            "restore_starts": self.restore_starts,
            "remote_restore_starts": self.remote_restore_starts,
            "snapshots_taken": sum(1 for e in self.events
                                   if e.kind == "snapshot"),
            "events": self.events,
        }
