"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, per the assignment:

    compute    = HLO_FLOPs  / peak_FLOP/s          (per-chip module)
    memory     = HLO_bytes  / HBM_bw
    collective = collective_bytes / link_bw

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the SPMD
partitioner emits a per-device module, so these are per-chip already).
collective_bytes is parsed from the compiled HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
we take max(result bytes, sum of operand bytes) — the traffic a chip puts on
ICI for that op (all-gather result > operand; reduce-scatter the reverse).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _split_computations(hlo_text: str) -> dict[str, str]:
    """Computation name -> body text (top-level blocks of the module)."""
    comps: dict[str, str] = {}
    starts = [(m.start(), m.group(1)) for m in _COMP_RE.finditer(hlo_text)]
    for i, (pos, name) in enumerate(starts):
        end = starts[i + 1][0] if i + 1 < len(starts) else len(hlo_text)
        comps[name] = hlo_text[pos:end]
    return comps


def _line_collective(line: str):
    """(op_kind, bytes) for a collective-defining line, else None."""
    if "=" not in line:
        return None
    for coll in _COLLECTIVES:
        pos = line.find(f" {coll}(")
        if pos < 0:
            pos = line.find(f" {coll}-start(")
        if pos < 0:
            continue
        head, tail = line[:pos], line[pos:]
        res = sum(_shape_bytes(d, s) for d, s in
                  _SHAPE_RE.findall(head.split("=", 1)[-1]))
        ops = sum(_shape_bytes(d, s) for d, s in
                  _SHAPE_RE.findall(tail))
        return coll, max(res, ops)
    return None


def parse_collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Per-collective byte totals from compiled HLO text, with while-loop
    bodies multiplied by their known trip counts (scan bodies execute
    trip_count times; a flat scan of the text would count them once)."""
    comps = _split_computations(hlo_text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    if m:
        entry = m.group(1)

    memo: dict[str, tuple[dict[str, float], dict[str, float]]] = {}

    def visit(name: str):
        if name in memo:
            return memo[name]
        totals = {c: 0.0 for c in _COLLECTIVES}
        counts = {c: 0.0 for c in _COLLECTIVES}
        memo[name] = (totals, counts)          # break cycles defensively
        body_text = comps.get(name, "")
        for line in body_text.splitlines():
            got = _line_collective(line)
            if got:
                totals[got[0]] += got[1]
                counts[got[0]] += 1
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                bt, bc = visit(body)
                for c in _COLLECTIVES:
                    totals[c] += trip * bt[c]
                    counts[c] += trip * bc[c]
                del cond
        memo[name] = (totals, counts)
        return memo[name]

    if entry is None:
        totals = {c: 0.0 for c in _COLLECTIVES}
        counts = {c: 0.0 for c in _COLLECTIVES}
        for line in hlo_text.splitlines():
            got = _line_collective(line)
            if got:
                totals[got[0]] += got[1]
                counts[got[0]] += 1
    else:
        totals, counts = visit(entry)
    return {"bytes_by_op": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip HLO bytes accessed
    coll_bytes: float            # per-chip collective bytes
    model_flops: float           # useful-math flops per chip (6ND etc.)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time lower bound = max of the three terms (perfect
        overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound:
        (useful flops / peak) / step_time."""
        ideal = self.model_flops / PEAK_FLOPS
        return ideal / self.step_s if self.step_s else 0.0

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "step_s": self.step_s, "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_per_chip(cfg, cell, n_chips: int, grad_accum: int = 1) -> \
        float:
    """6*N*D (train) / 2*N*D (inference) useful-math floor, active params
    for MoE, divided across chips."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        total = 6.0 * n_active * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per row
        total = 2.0 * n_active * cell.global_batch
    return total / n_chips


def cost_flops_bytes(cost: Any) -> tuple[float, float]:
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get("flops", 0.0)), \
        float(cost.get("bytes accessed", 0.0))


# ---------------------------------------------------------------------------
# HBM traffic model (memory roofline term)
#
# XLA's "bytes accessed" is unusable here: on the scanned module it counts
# while bodies once; on the unrolled unoptimized module it counts every
# pre-fusion intermediate.  Instead we model per-chip HBM traffic from the
# *actual sharded* spec trees (real shard shapes via NamedSharding):
#
#   decode :  weights (read once — decode is weight/cache-bound) + full live
#             cache read + O(1) cache write.  Precise: these two terms are
#             the entire story for single-token decode.
#   prefill:  weights + cache write + ACT_RW residual-sized activation
#             reads/writes per layer (documented heuristic; prefill is
#             compute-bound so the bound is insensitive to ACT_RW).
#   train  :  3x weight reads (fwd, remat recompute, bwd; FSDP all-gathers
#             re-materialize full per-layer weights on every chip, so reads
#             scale with the gathered size) + grad write/read + fp32
#             m/v/master read+write + 2x saved scan boundaries + 3x
#             activation traffic.
# ---------------------------------------------------------------------------

ACT_RW = 12          # residual-stream-sized tensor r/w per layer per pass


def tree_bytes_per_chip(spec_tree, mesh, rules) -> int:
    """Actual per-chip bytes of a ParamSpec tree under its shardings."""
    import math as _math
    import numpy as _np
    from repro.models.layers import tree_map_specs
    from repro.sharding import named_sharding
    total = 0

    def acc(s):
        nonlocal total
        if mesh is None:
            shard = s.shape
        else:
            shard = named_sharding(s.axes, s.shape, mesh, rules)\
                .shard_shape(s.shape)
        total += _math.prod(shard) * _np.dtype(s.dtype).itemsize

    tree_map_specs(acc, spec_tree)
    return total


def hbm_traffic_model(cfg, cell, mesh, rules, grad_accum: int = 1) -> float:
    """Per-chip HBM bytes per step (see block comment above)."""
    import math as _math
    from repro.models.model import cache_specs, param_specs
    pspecs = param_specs(cfg)
    p_shard = tree_bytes_per_chip(pspecs, mesh, rules)
    n_layers = cfg.num_layers + cfg.encoder_layers
    batch_axes = ("pod", "data")
    if rules and rules.get("batch") is not None:
        b = rules["batch"]
        batch_axes = (b,) if isinstance(b, str) else tuple(b)
    batch_shards = _math.prod(
        mesh.shape.get(a, 1) for a in batch_axes) if mesh else 1
    # weight reads re-materialize at the FSDP-gathered size: the stored
    # shard times the product of the gathered (w_embed) axes
    if rules and rules.get("w_embed") is not None:
        waxes = rules["w_embed"]
        waxes = (waxes,) if isinstance(waxes, str) else tuple(waxes)
    else:
        waxes = ()
    gather_x = _math.prod(
        mesh.shape.get(a, 1) for a in waxes) if mesh else 1

    if cell.kind == "decode":
        c_shard = tree_bytes_per_chip(
            cache_specs(cfg, cell.global_batch, cell.seq_len), mesh, rules)
        n_chips = _math.prod(mesh.shape.values()) if mesh else 1
        logits = cell.global_batch * cfg.vocab_size * 2 / n_chips
        return p_shard + c_shard + logits

    tokens_chip = cell.global_batch * cell.seq_len / batch_shards
    act = ACT_RW * n_layers * tokens_chip * cfg.d_model * 2

    if cell.kind == "prefill":
        c_shard = tree_bytes_per_chip(
            cache_specs(cfg, cell.global_batch, cell.seq_len), mesh, rules)
        return p_shard + c_shard + act

    # train: FSDP all-gather re-materializes per-layer weights on chip
    gathered = p_shard * gather_x
    n_shard_params = p_shard / 2                      # param count per chip
    weights = 3 * gathered
    grads = 2 * p_shard
    opt = 6 * 4 * n_shard_params                      # m, v, master r+w fp32
    from repro.launch.specs import scan_boundaries
    saved = 2 * (scan_boundaries(cfg) * tokens_chip * cfg.d_model * 2)
    return weights + grads + opt + 3 * act + saved
