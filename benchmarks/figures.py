"""One benchmark per paper table/figure (HotMem paper, Figs. 5-10).

All benchmarks run REAL device operations on CPU with reduced model configs;
the quantities compared (bytes migrated, metadata vs copy wall time, P99
parity, interference spikes) are the paper's hardware-independent claims.
Each returns (name, us_per_call, derived) rows for benchmarks.run.
"""
from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import (ClusterSim, FleetScheduler, HostMemoryBroker,
                           Router)
from repro.configs.base import get_config, reduced
from repro.core.arena import ArenaSpec
from repro.core.elastic import ElasticArena
from repro.models import model as M
from repro.serving.engine import ServeEngine
from repro.serving.request import PROFILES, Request
from repro.serving.tracegen import assign_profiles, bursty_trace

Row = tuple[str, float, str]


def _cfg_spec(partition_tokens=256, n_partitions=16):
    cfg = reduced(get_config("qwen2-7b"))
    spec = ArenaSpec.from_model(cfg, partition_tokens=partition_tokens,
                                n_partitions=n_partitions, block_tokens=32)
    return cfg, spec


def _pool(spec, feature=4096):
    """Device block pool holding realistic per-block bytes."""
    per_block = max(spec.bytes_per_block // 2, 2)   # bf16 elements
    return [jnp.zeros((spec.n_blocks, per_block), jnp.bfloat16)]


def _fill(arena, n, tokens, prefix="r"):
    for i in range(n):
        arena.admit(f"{prefix}{i}")
        arena.on_tokens(f"{prefix}{i}", tokens)


def _warmup(arena):
    """Trigger jit compiles of the copy/zero kernels outside timing."""
    arena.plug(0)


def _measure_unplug(mode, n_live, release, units, *, seed=0, repeats=3):
    """Median unplug wall time over fresh arenas (first run warms jits)."""
    times, last_ev = [], None
    for rep in range(repeats):
        cfg, spec = _cfg_spec(n_partitions=16)
        caches = _pool(spec) if mode == "vanilla" else None
        ar = ElasticArena(cfg, spec, mode, caches=caches, seed=seed + rep)
        _fill(ar, n_live, 256)
        for i in release:
            ar.finish(f"r{i}")
        t0 = time.perf_counter()
        last_ev = ar.unplug(units if mode == "hotmem"
                            else units * spec.blocks_per_partition)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times[1:])), last_ev, spec


def fig5_reclaim_latency_vs_size() -> list[Row]:
    """Paper Fig. 5: avg latency to reclaim different sizes.  The most
    recently admitted requests exit (the engine's keep-alive recycling
    order), then the runtime unplugs the freed size."""
    rows: list[Row] = []
    for n_parts in (2, 4, 8):
        release = list(range(14 - n_parts, 14))     # newest exit first
        h_us, ev_h, spec = _measure_unplug("hotmem", 14, release, n_parts)
        v_us, ev_v, _ = _measure_unplug("vanilla", 14, release, n_parts,
                                        seed=10)
        mb = n_parts * spec.bytes_per_partition / 2 ** 20
        rows.append((f"fig5/hotmem/{mb:.2f}MiB", h_us,
                     f"migrated_B=0 reclaimed={ev_h.reclaimed_units}"))
        rows.append((f"fig5/vanilla/{mb:.2f}MiB", v_us,
                     f"migrated_B={ev_v.migrated_bytes} "
                     f"speedup={v_us/max(h_us,1e-9):.1f}x"))
    return rows


def fig6_reclaim_vs_occupancy() -> list[Row]:
    """Paper Fig. 6: reclaim 2 partitions as occupancy rises — HotMem flat,
    vanilla grows with migrations."""
    rows: list[Row] = []
    for n_live in (4, 8, 12, 14):
        release = [n_live - 2, n_live - 1]
        h_us, _, _ = _measure_unplug("hotmem", n_live, release, 2)
        v_us, ev_v, _ = _measure_unplug("vanilla", n_live, release, 2,
                                        seed=20)
        occ = n_live / 16
        rows.append((f"fig6/hotmem/occ={occ:.2f}", h_us, "migrated_B=0"))
        rows.append((f"fig6/vanilla/occ={occ:.2f}", v_us,
                     f"migrated_B={ev_v.migrated_bytes}"))
    return rows


def fig7_reclaim_compute() -> list[Row]:
    """Paper Fig. 7: cumulative reclaim-path work shrinking a full arena
    stepwise — vanilla burns copy bandwidth, HotMem is metadata-only."""
    rows: list[Row] = []
    for mode in ("hotmem", "vanilla"):
        cfg, spec = _cfg_spec(n_partitions=32)
        caches = _pool(spec) if mode == "vanilla" else None
        ar = ElasticArena(cfg, spec, mode, caches=caches, seed=2)
        _fill(ar, 24, 256)
        for i in range(24):                      # all exit (load drop)
            ar.finish(f"r{i}")
        ar.unplug(0 if mode == "hotmem" else 0)  # noop warm
        total_us = 0.0
        migrated = 0
        steps = 0
        unit = 1 if mode == "hotmem" else spec.blocks_per_partition
        while ar.units() > (2 * unit if mode == "vanilla" else 2):
            t0 = time.perf_counter()
            ev = ar.unplug(unit)
            total_us += (time.perf_counter() - t0) * 1e6
            migrated += ev.migrated_bytes
            steps += 1
            if ev.reclaimed_units == 0:
                break
        rows.append((f"fig7/{mode}", total_us / max(steps, 1),
                     f"steps={steps} cum_migrated_B={migrated} "
                     f"cum_us={total_us:.0f}"))
    return rows


def _run_trace(mode, seed=5, duration=16.0):
    cfg, spec = _cfg_spec(partition_tokens=128, n_partitions=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    arr = bursty_trace(duration, 0.8, burst_x=6.0, burst_at=(0.0,),
                       burst_len=3.0, quiet_after=duration / 2, seed=seed)
    reqs = [Request(rid=f"{mode}{i}", profile=p, submit_s=t)
            for i, (t, p) in enumerate(assign_profiles(arr, PROFILES, seed))]
    eng = ServeEngine(cfg, params, spec, mode=mode, keep_alive=3.0,
                      seed=seed)
    return eng, eng.run(reqs, max_virtual_s=2000)


def fig8_trace_reclaim_throughput() -> list[Row]:
    """Paper Fig. 8: reclaim throughput (MiB/s) under a bursty trace."""
    rows: list[Row] = []
    for mode in ("hotmem", "vanilla"):
        _, m = _run_trace(mode)
        thr = (m["reclaimed_bytes"] / 2 ** 20) / max(m["reclaim_wall_s"],
                                                     1e-9)
        rows.append((f"fig8/{mode}", m["reclaim_wall_s"] * 1e6,
                     f"reclaimed_MiB={m['reclaimed_bytes']/2**20:.2f} "
                     f"MiB_per_s={thr:.1f}"))
    return rows


def fig9_p99_latency() -> list[Row]:
    """Paper Fig. 9: P99 request latency — elastic (hotmem/vanilla) vs
    statically over-provisioned."""
    rows: list[Row] = []
    for mode in ("hotmem", "vanilla", "static"):
        _, m = _run_trace(mode, seed=7)
        rows.append((f"fig9/{mode}", (m["latency_p99"] or 0) * 1e6,
                     f"p50_us={(m['latency_p50'] or 0)*1e6:.0f} "
                     f"completed={m['completed']}"))
    return rows


def fig10_interference() -> list[Row]:
    """Paper Fig. 10: co-tenant decode latency around scale-down events.
    A steady Cnn tenant decodes throughout while a bursty HTML tenant's
    instances are recycled mid-run (keep-alive expiry -> unplug); compares
    decode-step wall time near unplug events vs quiet periods."""
    rows: list[Row] = []
    for mode in ("hotmem", "vanilla"):
        cfg, spec = _cfg_spec(partition_tokens=128, n_partitions=8)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        steady = bursty_trace(20.0, 0.5, burst_x=1.0, burst_len=0.0,
                              seed=11)
        burst = bursty_trace(20.0, 0.4, burst_x=10.0, burst_at=(0.0,),
                             burst_len=2.5, quiet_after=3.0, seed=12)
        reqs = [Request(rid=f"c{i}", profile=PROFILES["cnn"], submit_s=t)
                for i, t in enumerate(steady)]
        reqs += [Request(rid=f"h{i}", profile=PROFILES["html"], submit_s=t)
                 for i, t in enumerate(burst)]
        eng = ServeEngine(cfg, params, spec, mode=mode, keep_alive=2.0,
                          seed=9)
        m = eng.run(reqs, max_virtual_s=2000)
        events = m["events"]
        unplug_ts = [e.t for e in events if e.kind == "unplug"]
        dec = [(e.t, e.wall_s) for e in events if e.kind == "decode"]
        near, far = [], []
        for t, w in dec:
            if any(0 <= t - ut < 0.5 for ut in unplug_ts):
                near.append(w)
            else:
                far.append(w)
        base = np.mean(far) if far else 0.0
        spike = (np.mean(near) / base) if near and base else 1.0
        # on this serial host the interference manifests as the unplug
        # stall itself (decode cannot run during the migration copies);
        # report the mean stall a co-tenant decode step sees per event
        stalls = [e.wall_s for e in events if e.kind == "unplug"]
        stall_us = np.mean(stalls) * 1e6 if stalls else 0.0
        rows.append((f"fig10/{mode}", base * 1e6,
                     f"decode_steps_near_unplug={len(near)} "
                     f"spike_ratio={spike:.2f} "
                     f"unplug_stall_us={stall_us:.0f} "
                     f"stall_vs_decode={stall_us/max(base*1e6,1e-9):.2f}x"))
    return rows


def kernel_layout_cost() -> list[Row]:
    """Kernel-level layout contrast (jitted oracle impls on CPU): decode
    attention over contiguous partitions vs block-table gather."""
    from repro.kernels import ops
    p, t, hkv, g, dh, bt = 8, 1024, 2, 4, 64, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(p, hkv, g, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(p, t, hkv, dh)), jnp.float32)
    pos = jnp.full((p,), t - 1, jnp.int32)
    nb = p * (t // bt)
    kp = k.reshape(nb, bt, hkv, dh)
    vp = v.reshape(nb, bt, hkv, dh)
    perm = rng.permutation(nb)                      # scattered placement
    inv = np.argsort(perm)
    tables = jnp.asarray(inv.reshape(p, t // bt), jnp.int32)
    kp, vp = kp[perm], vp[perm]

    def bench(fn, *args, iters=20):
        fn(*args).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e6

    part_us = bench(lambda *a: ops.partition_attention(*a, impl="ref"),
                    q, k, v, pos)
    paged_us = bench(lambda *a: ops.paged_attention(*a, impl="ref"),
                     q, kp, vp, tables, pos)
    return [("kernel/partition_attention", part_us, "contiguous rows"),
            ("kernel/paged_attention", paged_us,
             f"gather_overhead={paged_us/max(part_us,1e-9):.2f}x")]


def cluster_reclaim() -> list[Row]:
    """Host-level steal (paper §2 lifted to the cluster), sync vs async.

    Trace rows: two replicas share one ``HostMemoryBroker`` budget below 2
    full arenas.  Replica B serves early load then goes quiet (warm
    containers idling); replica A's burst then needs memory the free pool
    can't cover, so the broker reclaims from the idlest VM — B — either
    inline (sync: A serializes behind B's unplug) or via reclaim orders B
    drains between its ticks (async: A's stall is zero by construction).
    The value column is the requester-visible stall p99 in us — the
    paper's tail-latency contrast lifted to the host control plane.

    Pipeline rows: a scripted steal with identical demand on both paths —
    A's burst forces exactly one 6-partition steal from B — so the total
    units stolen are equal by construction and only the stall and its
    placement differ; ``overlap_decodes`` counts A's decode steps that ran
    while B's reclaim order was still draining (0 for sync: the reclaim
    completed inside A's plug request before A could decode again)."""
    rows: list[Row] = []
    for mode in ("hotmem", "vanilla"):
        for async_mode in (False, True):
            cfg, spec = _cfg_spec(partition_tokens=128, n_partitions=8)
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            bpp = spec.blocks_per_partition
            broker = HostMemoryBroker(budget_units=10 * bpp,
                                      async_reclaim=async_mode)
            engines = {rid: ServeEngine(cfg, params, spec, mode=mode,
                                        keep_alive=3.0, seed=i,
                                        broker=broker, replica_id=rid)
                       for i, rid in enumerate(("A", "B"))}
            quiet = bursty_trace(6.0, 0.9, burst_x=1.0, burst_len=0.0,
                                 seed=2)
            burst = [4.0 + t for t in bursty_trace(
                4.0, 3.0, burst_x=3.0, burst_at=(0.0,), burst_len=2.0,
                seed=3)]
            reqs = [Request(rid=f"b{i}", profile=p, submit_s=t)
                    for i, (t, p) in enumerate(
                        assign_profiles(quiet, PROFILES, 2))]
            reqs += [Request(rid=f"a{i}", profile=p, submit_s=t)
                     for i, (t, p) in enumerate(
                         assign_profiles(burst, PROFILES, 3))]
            sim = ClusterSim(
                engines,
                Router(route_fn=lambda r, e:
                       "B" if r.rid.startswith("b") else "A"),
                broker)
            m = sim.run(reqs, max_virtual_s=2000)
            broker.check_invariants()
            rep = m["broker"]["by_mode"].get(mode, {})
            stalls = broker.request_stalls or [0.0]
            p50 = float(np.percentile(stalls, 50)) * 1e6
            p99 = float(np.percentile(stalls, 99)) * 1e6
            tag = "async" if async_mode else "sync"
            rows.append((
                f"cluster_reclaim/{mode}/{tag}", p99,
                f"stall_p50_us={p50:.0f} stall_p99_us={p99:.0f} "
                f"steal_wall_us={rep.get('wall_seconds', 0.0) * 1e6:.0f} "
                f"steals={rep.get('steals', 0)} "
                f"stolen_units={rep.get('units', 0)} "
                f"migrated_B={rep.get('migrated_bytes', 0)} "
                f"lat_p99_us={(m['latency_p99'] or 0) * 1e6:.0f} "
                f"completed={m['completed']}/{len(reqs)}"))
        rows += _steal_pipeline_rows(mode)
    rows += _snapshot_restart_rows()
    rows += _fleet_migration_rows()
    return rows


def _fleet_migration_rows() -> list[Row]:
    """Fleet-level warm-state migration (TrEnv-X remote snapshot pools on
    the Squeezy fleet): the SAME function admitted on host A three ways —

      cold    — full prompt prefill (no cached state anywhere);
      local   — restored from A's own host pool (A captured it when its
                warm container expired);
      remote  — A's pool is empty but host B holds the snapshot: the
                fleet scheduler migrates it (debit B's pool, modeled
                inter-host copy over real payload bytes at the default
                bandwidth/link latency, credit A's pool) and A's restore
                pays that copy on top of its host->device row write.

    The value column is admitted->first-token in us, the MEDIAN of 3
    samples per path (single-shot restore walls are noise-dominated on a
    busy CPU — same repeat-and-median discipline as ``_measure_unplug``);
    the acceptance property is remote landing STRICTLY between local and
    cold (the copy is real but far cheaper than recomputing prefill)."""
    rows: list[Row] = []
    cfg, spec = _cfg_spec(partition_tokens=128, n_partitions=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    (local_us, remote_us, cold_us), sched, A = _fleet_migration_medians(
        cfg, params, spec, repeats=3)
    rest_ev = [e for e in A.events if e.kind == "restore"][-1]
    assert rest_ev.detail["source"] == "remote"
    rec = sched.migrations[-1]
    between = local_us < remote_us < cold_us
    rows.append(("cluster_reclaim/fleet_migration/local", local_us,
                 "path=restore source=local"))
    rows.append(("cluster_reclaim/fleet_migration/remote", remote_us,
                 f"path=restore source=remote origin={rec.src} "
                 f"copy_B={rec.nbytes} copy_us={rec.copy_seconds*1e6:.0f} "
                 f"migrations={len(sched.migrations)} "
                 f"between_local_and_cold={'yes' if between else 'NO'}"))
    rows.append(("cluster_reclaim/fleet_migration/cold", cold_us,
                 "path=prefill"))
    return rows


def _fleet_migration_medians(cfg, params, spec, repeats=3):
    """Measure median cold / local-restore / remote-migrated-restore TTFT
    for one function across a 2-host fleet (shared by the benchmark row
    and the slow fleet E2E test's ordering assertion).

    Per remote sample the full fleet cycle runs: host B cold-starts the
    function, its expiry captures to B's pool, the scheduler migrates to
    A's host (fresh copy charge each time — paid, never compounded), and
    A restores remotely.  Returns ((local, remote, cold) medians in us,
    scheduler, engine A)."""
    bpp = spec.blocks_per_partition
    sched = FleetScheduler()                   # default bandwidth/latency
    brokers = {h: HostMemoryBroker(budget_units=12 * bpp,
                                   snapshot_pool_units=4 * bpp)
               for h in ("h0", "h1")}
    for h, b in brokers.items():
        sched.add_host(h, b)
    A = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                    seed=0, broker=brokers["h0"], replica_id="A")
    B = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                    seed=1, broker=brokers["h1"], replica_id="B")
    sched.placements.update({"A": "h0", "B": "h1"})
    empty = deque()

    def run_one(eng, rid):
        eng.submit(Request(rid=rid, profile=PROFILES["cnn"],
                           submit_s=eng.now))
        while eng.active or eng.pending:
            eng._tick(empty)
        req = next(r for r in eng.done if r.rid == rid)
        return (req.first_token_s - req.admitted_s) * 1e6

    def expire_warm(eng):
        eng.now += eng.keep_alive + 1.0
        eng._recycle_idle()

    for eng, rid in ((A, "jitA"), (B, "jitB")):    # compile out of band
        run_one(eng, rid)
        for prof, entries in list(eng.warm.items()):
            for (_, wrid, _row) in entries:        # drop without capturing
                eng.arena.finish(wrid)
            eng.warm[prof] = []

    # interleave the three paths within each round: wall-clock drift on a
    # busy CPU (allocator/cache warmup across tens of ms) is larger than
    # the modeled copy, so per-path phases would bias the comparison —
    # adjacent samples see the same machine state
    cold, local, remote = [], [], []
    for i in range(repeats):
        cold.append(run_one(A, f"c{i}"))       # cold: nothing cached
        expire_warm(A)                         # expiry captures on h0
        local.append(run_one(A, f"s{i}"))      # local: A's OWN pool
        expire_warm(A)                         # restorable: discard row
        brokers["h0"].snapshot_drop("cnn")
        run_one(B, f"bc{i}")                   # B cold-starts...
        expire_warm(B)                         # ...and captures on h1
        rec = sched.ensure_local("cnn", "h0")  # THE cross-host migration
        assert rec is not None
        remote.append(run_one(A, f"r{i}"))     # pays rec.copy_seconds
        expire_warm(A)
        brokers["h0"].snapshot_drop("cnn")     # reset for the next round
        sched.check_invariants()
    med = lambda xs: float(np.median(xs))
    return (med(local), med(remote), med(cold)), sched, A


def _snapshot_restart_rows() -> list[Row]:
    """Host snapshot pool (TrEnv-X-style warm restarts on the Squeezy
    broker), two contrasts:

    TTFT rows: one hotmem engine runs the same function cold (prefill),
    warm (kept-alive adopt), and restored (its warm container expired but
    the partition was copied out to the host pool first) — the value
    column is admitted->first-token in us.  Restore lands strictly
    between the warm adopt and the cold prefill: it pays one host->device
    row copy but no model compute.

    Squeeze rows: the same spare capacity held either AS snapshots (the
    host's segregated bounded-lifetime region) or INSIDE an idle victim
    VM (kept-alive containers).  The same pressured plug request is then
    covered by an LRU snapshot drop — metadata-only, zero migration, no
    ``ReclaimOrder`` — versus a reclaim order the victim must drain."""
    rows: list[Row] = []
    cfg, spec = _cfg_spec(partition_tokens=128, n_partitions=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    bpp = spec.blocks_per_partition
    broker = HostMemoryBroker(budget_units=12 * bpp,
                              snapshot_pool_units=4 * bpp)
    eng = ServeEngine(cfg, params, spec, mode="hotmem", keep_alive=2.0,
                      seed=0, broker=broker, replica_id="A")
    empty = deque()

    def run_one(rid):
        eng.submit(Request(rid=rid, profile=PROFILES["cnn"],
                           submit_s=eng.now))
        while eng.active or eng.pending:
            eng._tick(empty)
        req = next(r for r in eng.done if r.rid == rid)
        return (req.first_token_s - req.admitted_s) * 1e6

    run_one("jit0")                  # compile prefill+decode out of band
    for prof, entries in list(eng.warm.items()):
        for (_, rid, _row) in entries:   # drop the jit-warm container
            eng.arena.finish(rid)        # (without snapshotting it)
        eng.warm[prof] = []
    cold_us = run_one("c0")
    warm_us = run_one("w0")              # adopts c0's kept-alive row
    eng.now += eng.keep_alive + 1.0
    eng._recycle_idle()                  # expiry -> capture to the pool
    restore_us = run_one("s0")           # restores from the pool
    snap_ev = [e for e in eng.events if e.kind == "snapshot"][-1]
    rest_ev = [e for e in eng.events if e.kind == "restore"][-1]
    between = warm_us < restore_us < cold_us
    rows.append(("cluster_reclaim/snapshot_ttft/cold", cold_us,
                 "path=prefill"))
    rows.append(("cluster_reclaim/snapshot_ttft/warm", warm_us,
                 "path=adopt copy_B=0"))
    rows.append(("cluster_reclaim/snapshot_ttft/restore", restore_us,
                 f"path=restore copy_B={rest_ev.detail['bytes']} "
                 f"restore_us={rest_ev.wall_s * 1e6:.0f} "
                 f"capture_us={snap_ev.wall_s * 1e6:.0f} "
                 f"between_warm_and_cold={'yes' if between else 'NO'}"))

    def pressured_grant(spare_as_snapshots: bool):
        b = HostMemoryBroker(budget_units=12, async_reclaim=True,
                             snapshot_pool_units=4
                             if spare_as_snapshots else None)
        orders = deque()
        if spare_as_snapshots:
            b.register("A", 4, load=lambda: 9, order_sink=orders.append,
                       mode="hotmem")
            b.register("B", 4, load=lambda: 0, order_sink=orders.append,
                       mode="hotmem")
            assert b.snapshot_put("cnn", units=2, nbytes=1 << 20)
            assert b.snapshot_put("bert", units=2, nbytes=1 << 20)
        else:
            b.register("A", 4, load=lambda: 9, order_sink=orders.append,
                       mode="hotmem")
            b.register("B", 8, load=lambda: 0, order_sink=orders.append,
                       mode="hotmem")      # spare lives inside the victim
        t0 = time.perf_counter()
        g = b.request_grant("A", 4)
        us = (time.perf_counter() - t0) * 1e6
        b.check_invariants()
        rep = b.report()
        return us, g, len(orders), rep

    us_p, g_p, orders_p, rep_p = pressured_grant(True)
    us_v, g_v, orders_v, rep_v = pressured_grant(False)
    rows.append(("cluster_reclaim/snapshot_squeeze/pool", us_p,
                 f"granted_now={g_p.granted} pending={g_p.pending} "
                 f"orders={orders_p} squeezed_units={rep_p['squeezed_units']} "
                 f"migrated_B=0"))
    rows.append(("cluster_reclaim/snapshot_squeeze/victim", us_v,
                 f"granted_now={g_v.granted} pending={g_v.pending} "
                 f"orders={orders_v} squeezed_units={rep_v['squeezed_units']} "
                 f"victim_owes={rep_v['pending_units']}"))
    return rows


def _steal_pipeline_rows(mode) -> list[Row]:
    """Scripted steal with identical demand for sync and async (see
    ``cluster_reclaim``): equal units stolen, only the stall differs."""
    rows: list[Row] = []
    stolen = {}
    for async_mode in (False, True):
        cfg, spec = _cfg_spec(partition_tokens=128, n_partitions=8)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        bpp = spec.blocks_per_partition
        broker = HostMemoryBroker(budget_units=10 * bpp,
                                  async_reclaim=async_mode)
        mk = lambda rid, seed: ServeEngine(
            cfg, params, spec, mode=mode, keep_alive=1e9, headroom=0,
            seed=seed, prewarm=False, broker=broker, replica_id=rid)
        A, B = mk("A", 0), mk("B", 1)
        # B grows to the full arena and parks 8 kept-alive containers
        B.arena.plug(6 if mode == "hotmem" else
                     6 * spec.blocks_per_partition)
        B._sync_rows(B._units())
        for i in range(8):
            row = B.arena.admit(f"w{i}")
            # a full-partition footprint, so the drain frees exactly one
            # container per partition in BOTH layouts (vanilla otherwise
            # drains its lazy-allocation headroom first and legitimately
            # re-grows afterwards, breaking the equal-demand construction)
            B.arena.on_tokens(f"w{i}", spec.partition_tokens)
            B.warm.setdefault("cnn", []).append(
                (0.0, f"w{i}", row if row is not None else i))
        # A's burst: 5 invocations -> demand 5 -> bucket 8; the free pool
        # is empty, so A's resize must take 6 partitions from B
        for i in range(5):
            A.submit(Request(rid=f"q{i}", profile=PROFILES["cnn"],
                             submit_s=0.0))
        empty_a, empty_b = deque(), deque()
        overlap = 0
        for _ in range(3000):
            pend_before = broker.pending_units()
            A._tick(empty_a)
            if pend_before > 0 and A.events and \
                    A.events[-1].kind == "decode":
                overlap += 1
            if broker.pending_units() > 0 or B._reclaim_orders:
                B._tick(empty_b)
            if not A.active and not A.pending \
                    and broker.pending_units() == 0:
                break
        broker.check_invariants()
        stalls = broker.request_stalls or [0.0]
        p99 = float(np.percentile(stalls, 99)) * 1e6
        tag = "async" if async_mode else "sync"
        stolen[tag] = sum(r.units for r in broker.steal_log)
        rows.append((
            f"cluster_reclaim_pipeline/{mode}/{tag}", p99,
            f"stall_p99_us={p99:.0f} "
            f"steal_wall_us={sum(r.wall_seconds for r in broker.steal_log) * 1e6:.0f} "
            f"stolen_units={stolen[tag]} "
            f"overlap_decodes={overlap} "
            f"completed={len(A.done)}"))
    assert stolen["sync"] == stolen["async"], \
        f"steal totals diverged: {stolen}"
    return rows


def snapshot_data_plane() -> list[Row]:
    """Snapshot data plane, fused vs per-leaf (the PR-10 tentpole
    contrast): capture (device gather + device->host) and restore
    (host->device + scatter) of one arena row — the fused path stages
    every leaf through ONE launch and ONE transfer via the kv_snapshot
    twins, the legacy path pays one dispatch/transfer per leaf."""
    rng = np.random.default_rng(0)
    cfg, spec = _cfg_spec(partition_tokens=128, n_partitions=8)
    caches = jax.tree.map(
        lambda x: jnp.asarray(rng.standard_normal(x.shape), dtype=x.dtype),
        M.init_caches(cfg, 8, spec.partition_tokens))
    layout = M.cache_row_layout(caches)
    n_leaves = len(layout.slots)
    row = 3
    rows_ix = jnp.asarray([row], jnp.int32)

    def med_us(fn, repeats=5):
        fn()                                     # warm compiles
        walls = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            walls.append((time.perf_counter() - t0) * 1e6)
        return float(np.median(walls))

    legacy_cap_us = med_us(
        lambda: jax.device_get(M.cache_read_row(caches, row)))
    fused_cap_us = med_us(lambda: jax.device_get(
        M.cache_read_rows(caches, rows_ix, layout=layout, impl="ref")))
    host_tree = jax.device_get(M.cache_read_row(caches, row))
    host_blob = np.asarray(jax.device_get(
        M.cache_read_rows(caches, rows_ix, layout=layout, impl="ref")))

    def legacy_restore():
        rc = jax.tree.map(jnp.asarray, host_tree)
        out = M.cache_write_row(caches, rc, row)
        jax.block_until_ready(jax.tree.leaves(out)[0])

    def fused_restore():
        out = M.cache_write_rows(caches, jnp.asarray(host_blob), rows_ix,
                                 layout=layout, impl="ref")
        jax.block_until_ready(jax.tree.leaves(out)[0])

    legacy_rest_us = med_us(legacy_restore)
    fused_rest_us = med_us(fused_restore)
    return [
        ("snapshot_plane/capture/legacy", legacy_cap_us,
         f"transfers={n_leaves} (one per leaf)"),
        ("snapshot_plane/capture/fused", fused_cap_us,
         f"transfers=1 leaves={n_leaves} row_B={layout.row_bytes} "
         f"speedup={legacy_cap_us / max(fused_cap_us, 1e-9):.2f}x"),
        ("snapshot_plane/restore/legacy", legacy_rest_us,
         f"transfers={n_leaves} (one per leaf)"),
        ("snapshot_plane/restore/fused", fused_rest_us,
         f"transfers=1 leaves={n_leaves} "
         f"speedup={legacy_rest_us / max(fused_rest_us, 1e-9):.2f}x"),
    ]


def print_trajectory() -> None:
    """The committed regression baselines side by side: per scenario
    family the row count + median tight-tier TTFT p99 (BENCH_6..9), and
    the device-bench cells (BENCH_10) next to them — the perf trajectory
    at a glance (``python -m benchmarks.figures --trajectory``)."""
    import json
    import os
    bench_dir = os.path.dirname(__file__)

    print("scenario families (BENCH_6..9):")
    fam: dict[str, list] = {}
    for fname in ("BENCH_6.json", "BENCH_7.json", "BENCH_8.json",
                  "BENCH_9.json"):
        path = os.path.join(bench_dir, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for name, row in json.load(f).items():
                tier = (row.get("ttft_p99_ms_by_tier") or {})
                vals = [v for v in tier.values() if v is not None]
                fam.setdefault(row.get("family", "?"), []).append(
                    (name, min(vals) if vals else None))
    for family in sorted(fam):
        vals = [v for _, v in fam[family] if v is not None]
        med = f"{float(np.median(vals)):8.1f}" if vals else "     n/a"
        print(f"  {family:<12} scenarios={len(fam[family]):>2} "
              f"ttft_p99_ms~{med}")

    print("device bench (BENCH_10):")
    path = os.path.join(bench_dir, "BENCH_10.json")
    if not os.path.exists(path):
        print("  (no BENCH_10.json committed yet)")
        return
    with open(path) as f:
        cells = json.load(f)
    for name in sorted(cells):
        r = cells[name]
        print(f"  {name:<36} capture_us={r['capture_us']:7.1f} "
              f"restore_us={r['restore_us']:7.1f} "
              f"bytes={r['blob_bytes']:>7} ratio={r['capture_ratio']:.2f}")


ALL = [fig5_reclaim_latency_vs_size, fig6_reclaim_vs_occupancy,
       fig7_reclaim_compute, fig8_trace_reclaim_throughput,
       fig9_p99_latency, fig10_interference, kernel_layout_cost,
       cluster_reclaim, snapshot_data_plane]


if __name__ == "__main__":
    import sys
    if "--trajectory" in sys.argv:
        print_trajectory()
    else:
        print("name,us_per_call,derived")
        for _fn in ALL:
            for _name, _us, _derived in _fn():
                print(f"{_name},{_us:.1f},{_derived}")
