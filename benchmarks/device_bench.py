"""Device-level snapshot data-plane benchmark (the BENCH_10 trajectory).

Measures the fused capture / restore kernels (``repro.kernels.kv_snapshot``
via the ``models.model`` row twins) per (config x partition_tokens x rows x
page size) cell, against each kernel's analytic roofline bytes model:

  capture_us   — one fused gather launch + ONE device->host blob copy
  restore_us   — ONE host->device blob copy + one fused scatter launch
  paginate_us  — host-side content hashing of the staged blob (page cells)
  expected / measured bytes + roofline_ratio — the staged bytes actually
  moved vs the bytes the CACHE SPECS say one row must move (independent
  code paths: a silent layout change, padding drift, or a double transfer
  shows up as ratio drift and fails the gate)

Rows land in ``BENCH_10.json`` under the scenario bank's own
``--check`` / ``--update-baseline`` discipline (benchmarks.run --device):
bytes fields must match the baseline EXACTLY, roofline ratios must stay
within the 2x band, and wall fields get a generous slack
(``WALL_SLACK``; CI machines are noisy, so this catches order-of-
magnitude regressions — e.g. accidentally timing interpret mode — not
scheduling jitter).

Off-TPU the timed impl is ``ref`` (one fused XLA executable; interpret-
mode tracing overhead would drown the signal — the same discipline the
serving engine uses); ``--smoke`` instead forces the Pallas kernel in
interpret mode on one tiny cell and cross-checks it bit-identical
against ref, so the kernel path itself stays covered in fast CI.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

WALL_SLACK = 5.0            # wall fields may drift this much before failing
RATIO_BAND = 2.0            # roofline expected-vs-measured bytes band
REPEATS = 5

# (config, partition_tokens, n_rows, page_bytes): attention-only,
# SSM/hybrid (state + conv leaves), and rglru-hybrid cache trees, each at
# unpaged and paged data planes, small and larger rows batches
CELLS = [
    ("qwen2-7b", 128, 1, None),
    ("qwen2-7b", 128, 1, 4096),
    ("qwen2-7b", 256, 2, 16384),
    ("mamba2-780m", 128, 1, 4096),
    ("recurrentgemma-2b", 128, 2, None),
    ("recurrentgemma-2b", 256, 1, 8192),
]
SMOKE_CELLS = [("qwen2-7b", 64, 1, 2048)]


def cell_name(config: str, t: int, n: int, pb) -> str:
    return f"{config}/t{t}/rows{n}/page{pb if pb else 'none'}"


def _random_caches(cfg, rows: int, t: int, *, seed: int):
    """Cache tree with non-degenerate contents (cache leaves are zero-
    initialized, which would make byte-identity checks vacuous and page
    digests all collide)."""
    from repro.models import model as M
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda x: jnp.asarray(rng.standard_normal(x.shape), dtype=x.dtype),
        M.init_caches(cfg, rows, t))


def _median_us(fn, repeats=REPEATS) -> float:
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        walls.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(walls))


def run_cell(config: str, t: int, n: int, page_bytes, *, impl: str) -> dict:
    from repro.configs.base import get_config, reduced
    from repro.kernels import kv_snapshot
    from repro.models import model as M
    from repro.serving.engine import assemble_pages, paginate_blob

    cfg = reduced(get_config(config))
    arena_rows = max(4, n + 1)
    caches = _random_caches(cfg, arena_rows, t, seed=0)
    layout = M.cache_row_layout(caches)
    rows = jnp.arange(n, dtype=jnp.int32)

    # -------- capture: fused gather + one device_get (first call warms jit)
    def capture():
        blob = M.cache_read_rows(caches, rows, layout=layout, impl=impl)
        return np.asarray(jax.device_get(blob))

    host = capture()
    capture_us = _median_us(capture)
    measured_d2h = int(host.nbytes)

    # -------- restore: one h2d of the blob + fused scatter (warm first)
    def restore():
        dev = jnp.asarray(host)
        out = M.cache_write_rows(caches, dev, rows, layout=layout,
                                 impl=impl)
        jax.block_until_ready(out)
        return out

    restored = restore()
    restore_us = _median_us(restore)
    measured_h2d = int(host.nbytes)

    # round-trip must be lossless (every cell, every run)
    got = np.asarray(jax.device_get(
        M.cache_read_rows(restored, rows, layout=layout, impl=impl)))
    assert got.tobytes() == host.tobytes(), "capture/restore round-trip drift"

    # -------- pagination: host-side hashing of the staged byte image
    blob_u8 = host.view(np.uint8).reshape(-1)
    paginate_us = None
    if page_bytes is not None:
        units = 8  # representative per-partition block charge

        def paginate():
            return paginate_blob(blob_u8, units, page_bytes)

        specs = paginate()
        paginate_us = _median_us(paginate)
        assert assemble_pages(specs).tobytes() == blob_u8.tobytes(), \
            "paginate/assemble round-trip drift"

    # -------- roofline: bytes the cache SPECS say one row must move
    expected_rb = kv_snapshot.expected_row_bytes(cfg, t)
    cap_model = kv_snapshot.capture_cost(expected_rb, n)
    rest_model = kv_snapshot.restore_cost(expected_rb, n)
    return {
        "config": config,
        "partition_tokens": t,
        "n_rows": n,
        "page_bytes": page_bytes,
        "impl": impl,
        "row_bytes": int(layout.row_bytes),
        "blob_bytes": measured_d2h,
        "expected_bytes": int(cap_model["host_bytes"]),
        "capture_us": capture_us,
        "capture_ratio": measured_d2h / cap_model["host_bytes"],
        "capture_roofline_s": cap_model["memory_s"],
        "restore_us": restore_us,
        "restore_ratio": measured_h2d / rest_model["host_bytes"],
        "restore_roofline_s": rest_model["memory_s"],
        "paginate_us": paginate_us,
        "pages": None if page_bytes is None else len(specs),
    }


def run_cells(*, smoke: bool = False) -> dict:
    """Run the bench grid.  Full mode times the engine's own impl (ref
    off-TPU); smoke mode forces the Pallas kernel (interpret off-TPU) on
    one tiny cell and cross-checks it against ref bit-identically."""
    on_tpu = jax.default_backend() == "tpu"
    rows = {}
    if smoke:
        for config, t, n, pb in SMOKE_CELLS:
            row = run_cell(config, t, n, pb, impl="pallas")
            _check_pallas_vs_ref(config, t, n)
            rows[cell_name(config, t, n, pb)] = row
        return rows
    impl = "pallas" if on_tpu else "ref"
    for config, t, n, pb in CELLS:
        rows[cell_name(config, t, n, pb)] = run_cell(config, t, n, pb,
                                                     impl=impl)
    return rows


def _check_pallas_vs_ref(config: str, t: int, n: int) -> None:
    """The interpret-mode Pallas kernels must stage the exact bytes the
    ref oracles stage (the smoke gate's correctness half)."""
    from repro.configs.base import get_config, reduced
    from repro.models import model as M

    cfg = reduced(get_config(config))
    caches = _random_caches(cfg, n + 2, t, seed=7)
    layout = M.cache_row_layout(caches)
    rows = jnp.arange(n, dtype=jnp.int32)
    a = np.asarray(jax.device_get(
        M.cache_read_rows(caches, rows, layout=layout, impl="pallas")))
    b = np.asarray(jax.device_get(
        M.cache_read_rows(caches, rows, layout=layout, impl="ref")))
    assert a.tobytes() == b.tobytes(), "pallas capture != ref capture"


def check_rows(rows: dict, baseline: dict) -> list[str]:
    """Gate the new run against the committed BENCH_10 baseline.  Bytes
    must match exactly, roofline ratios must sit in the 2x band, walls
    get WALL_SLACK."""
    failures = []
    exact = ("row_bytes", "blob_bytes", "expected_bytes", "pages")
    ratios = ("capture_ratio", "restore_ratio")
    walls = ("capture_us", "restore_us", "paginate_us")
    for name, old in sorted(baseline.items()):
        new = rows.get(name)
        if new is None:
            failures.append(f"{name}: missing from the new run")
            continue
        for f in exact:
            if new.get(f) != old.get(f):
                failures.append(f"{name}.{f}: {new.get(f)} vs baseline "
                                f"{old.get(f)} (must match exactly)")
        for f in ratios:
            r = new.get(f)
            if r is None or not (1.0 / RATIO_BAND < r < RATIO_BAND):
                failures.append(f"{name}.{f}: {r} outside the "
                                f"{RATIO_BAND}x roofline band")
        for f in walls:
            ov, nv = old.get(f), new.get(f)
            if ov is None:
                continue
            if nv is None:
                failures.append(f"{name}.{f}: vanished (baseline {ov})")
            elif nv > ov * WALL_SLACK:
                failures.append(f"{name}.{f}: {nv:.1f}us vs baseline "
                                f"{ov:.1f}us (> {WALL_SLACK}x slack)")
    return failures
