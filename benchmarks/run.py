"""Benchmark runner: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig5]

Prints ``name,us_per_call,derived`` CSV.  Roofline terms per (arch x shape x
mesh) come from the dry-run (see repro.launch.dryrun and EXPERIMENTS.md);
these benchmarks measure the paper's behavioural claims with real device ops
on reduced configs.
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import figures

    print("name,us_per_call,derived")
    for fn in figures.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
