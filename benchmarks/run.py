"""Benchmark runner: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig5]

Prints ``name,us_per_call,derived`` CSV.  Roofline terms per (arch x shape x
mesh) come from the dry-run (see repro.launch.dryrun and EXPERIMENTS.md);
these benchmarks measure the paper's behavioural claims with real device ops
on reduced configs.

Scenario mode — the SLO-tiered multi-tenant regression surface:

  PYTHONPATH=src python -m benchmarks.run --scenarios [--smoke] [--seed N]
      [--check] [--update-baseline] [--baseline PATH]

Runs the ``repro.cluster.scenarios`` bank (deterministic ModelReplica
fleet: no device ops, bit-identical rows for a fixed seed) and writes the
rows to the baseline files under ``--update-baseline``, or compares
against the committed baselines under ``--check``: any scenario missing
from the new run fails, and any time-valued field (``TIME_FIELDS`` + the
per-tier TTFT p99s) regressing more than 20% over baseline fails.
``--smoke`` restricts to the smallest scenario per family (the fast-CI
subset); ``--check`` always runs the full bank so the gate covers every
committed row.

Baselines are split by PR of origin so each file stays an append-only
artifact: ``BENCH_6.json`` carries the single-device bank,
``BENCH_7.json`` the mesh family (sharded hosts), ``BENCH_8.json`` the
autoscale family (host lifecycle + drain-via-migration), and
``BENCH_9.json`` the dedup family (content-addressed snapshot pages).
``--check`` merges every committed file; ``--update-baseline`` rewrites
each row into the file that owns its family.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REGRESSION_SLACK = 1.2          # fail --check if new > old * this
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_6.json")
MESH_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_7.json")
MESH_FAMILIES = ("mesh",)       # families whose rows live in BENCH_7
AUTOSCALE_BASELINE = os.path.join(os.path.dirname(__file__),
                                  "BENCH_8.json")
AUTOSCALE_FAMILIES = ("autoscale",)  # families whose rows live in BENCH_8
DEDUP_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_9.json")
DEDUP_FAMILIES = ("dedup",)     # families whose rows live in BENCH_9
DEVICE_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_10.json")


def _time_values(row: dict) -> dict:
    """The fields the regression gate compares: scalar time medians plus
    the per-tier TTFT p99 map, flattened to ``field`` / ``field.tier``."""
    from repro.cluster.scenarios import TIME_FIELDS
    out = {}
    for f in TIME_FIELDS:
        if row.get(f) is not None:
            out[f] = row[f]
    for tier, v in (row.get("ttft_p99_ms_by_tier") or {}).items():
        if v is not None:
            out[f"ttft_p99_ms_by_tier.{tier}"] = v
    return out


def _baseline_files(args) -> list[str]:
    """Every committed baseline the gate covers: the primary file plus
    the per-family shards (each skipped only if it was never written)."""
    files = [args.baseline]
    if os.path.abspath(args.baseline) == os.path.abspath(DEFAULT_BASELINE):
        for shard in (MESH_BASELINE, AUTOSCALE_BASELINE, DEDUP_BASELINE):
            if os.path.exists(shard):
                files.append(shard)
    return files


def run_scenarios(args) -> int:
    from repro.cluster.scenarios import SMOKE, run_bank

    names = list(SMOKE) if args.smoke and not args.check else None
    rows = run_bank(names, seed=args.seed)
    for name in sorted(rows):
        r = rows[name]
        print(f"{name}: requests={r['requests']} completed={r['completed']} "
              f"killed={r['killed']} p99_by_tier={r['ttft_p99_ms_by_tier']}")

    if args.update_baseline:
        mesh = {n: r for n, r in rows.items()
                if r["family"] in MESH_FAMILIES}
        autoscale = {n: r for n, r in rows.items()
                     if r["family"] in AUTOSCALE_FAMILIES}
        dedup = {n: r for n, r in rows.items()
                 if r["family"] in DEDUP_FAMILIES}
        main_rows = {n: r for n, r in rows.items()
                     if n not in mesh and n not in autoscale
                     and n not in dedup}
        for path, part in ((args.baseline, main_rows),
                           (MESH_BASELINE, mesh),
                           (AUTOSCALE_BASELINE, autoscale),
                           (DEDUP_BASELINE, dedup)):
            if not part:
                continue
            with open(path, "w") as f:
                json.dump(part, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"baseline written: {path} ({len(part)} scenarios)")
        return 0

    if args.check:
        base = {}
        for path in _baseline_files(args):
            with open(path) as f:
                part = json.load(f)
            dup = set(base) & set(part)
            assert not dup, f"scenario in two baseline files: {sorted(dup)}"
            base.update(part)
        failures = []
        for name, old in sorted(base.items()):
            new = rows.get(name)
            if new is None:
                failures.append(f"{name}: missing from the new run")
                continue
            olds, news = _time_values(old), _time_values(new)
            for field, ov in sorted(olds.items()):
                nv = news.get(field)
                if nv is None:
                    failures.append(f"{name}.{field}: vanished "
                                    f"(baseline {ov})")
                elif ov > 0 and nv > ov * REGRESSION_SLACK:
                    failures.append(
                        f"{name}.{field}: {nv} vs baseline {ov} "
                        f"(+{100.0 * (nv / ov - 1.0):.0f}% > "
                        f"{100.0 * (REGRESSION_SLACK - 1.0):.0f}% slack)")
        if failures:
            print(f"\n--check FAILED ({len(failures)}):")
            for msg in failures:
                print(f"  {msg}")
            return 1
        print(f"\n--check ok: {len(base)} scenarios within "
              f"{100.0 * (REGRESSION_SLACK - 1.0):.0f}% of baseline")
    return 0


def run_device(args) -> int:
    """The snapshot data-plane bench (BENCH_10): fused capture/restore
    walls, staged bytes, and roofline expected-vs-measured ratios per
    (config x shape x page size) cell — same --check/--update-baseline
    discipline as the scenario bank, but bytes gate EXACTLY, ratios gate
    on the 2x roofline band, and walls get device_bench.WALL_SLACK."""
    from benchmarks import device_bench

    rows = device_bench.run_cells(smoke=args.smoke and not args.check)
    failures = []
    for name in sorted(rows):
        r = rows[name]
        pag = "" if r["paginate_us"] is None else \
            f" paginate_us={r['paginate_us']:.1f} pages={r['pages']}"
        print(f"{name}: capture_us={r['capture_us']:.1f} "
              f"restore_us={r['restore_us']:.1f} bytes={r['blob_bytes']} "
              f"capture_ratio={r['capture_ratio']:.3f} "
              f"restore_ratio={r['restore_ratio']:.3f} "
              f"impl={r['impl']}{pag}")
        # the roofline band gates EVERY run (smoke included), baseline or
        # not: measured bytes drifting from the specs model is a bug now
        for f in ("capture_ratio", "restore_ratio"):
            band = device_bench.RATIO_BAND
            if not (1.0 / band < r[f] < band):
                failures.append(f"{name}.{f}: {r[f]:.3f} outside the "
                                f"{band}x roofline band")

    if args.update_baseline:
        with open(DEVICE_BASELINE, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"baseline written: {DEVICE_BASELINE} ({len(rows)} cells)")
    elif args.check:
        with open(DEVICE_BASELINE) as f:
            base = json.load(f)
        failures += device_bench.check_rows(rows, base)

    if failures:
        print(f"\n--device check FAILED ({len(failures)}):")
        for msg in failures:
            print(f"  {msg}")
        return 1
    if args.check:
        print(f"\n--device check ok: {len(rows)} cells (bytes exact, "
              f"roofline within {device_bench.RATIO_BAND}x, walls within "
              f"{device_bench.WALL_SLACK}x)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--scenarios", action="store_true",
                    help="run the multi-tenant scenario bank instead of "
                         "the device benchmarks")
    ap.add_argument("--device", action="store_true",
                    help="run the snapshot data-plane device bench "
                         "(BENCH_10: fused capture/restore kernels vs "
                         "their roofline bytes models)")
    ap.add_argument("--smoke", action="store_true",
                    help="scenario mode: smallest scenario per family "
                         "only; device mode: one tiny cell on the Pallas "
                         "interpret path, cross-checked against ref")
    ap.add_argument("--check", action="store_true",
                    help="scenario mode: compare the full bank against the "
                         "committed baseline; exit 1 on >20%% regression")
    ap.add_argument("--update-baseline", action="store_true",
                    help="scenario mode: rewrite the baseline file")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="scenario baseline path (default benchmarks/"
                         "BENCH_6.json)")
    ap.add_argument("--seed", type=int, default=0,
                    help="scenario bank seed (baseline is seed 0)")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    if args.scenarios:
        raise SystemExit(run_scenarios(args))
    if args.device:
        raise SystemExit(run_device(args))

    from benchmarks import figures

    print("name,us_per_call,derived")
    for fn in figures.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
